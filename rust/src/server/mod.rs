//! HTTP entrypoint (vLLM-style): the conversation-first v1 API plus the
//! legacy one-shot endpoints.
//!
//! Hand-rolled HTTP/1.1 over std TCP (no tokio in the offline build — see
//! DESIGN.md §7). The server drives any [`EngineDriver`] — one engine or a
//! replica [`crate::cluster::Cluster`] (every submission is routed; session
//! turns are sticky-routed to their conversation's replica).
//!
//! Concurrency architecture (DESIGN.md §17): the engine is owned
//! EXCLUSIVELY by the driver thread — there is no engine mutex for handler
//! threads to contend on. Handlers interact with it only by enqueuing
//! commands onto an MPSC submit queue ([`Shared::call`]); the driver
//! drains the queue FIFO between steps and executes each command with the
//! engine and the shared state in hand. Completion delivery goes the other
//! way through the sharded [`WaiterTable`]: each submission registers a
//! per-request wait slot / stream sink / pipeline group in the same
//! command that submits it (so no step can slip an output past the
//! registration), and the driver routes step emissions straight into
//! those slots. Session state lives in the sharded
//! [`SessionManager`] on [`Shared`], so snapshot reads (`GET
//! /v1/sessions`, turn aborts) never touch the driver at all. A single
//! driver thread still interleaves {drain commands}{step} sequentially,
//! so single-threaded figures and per-request token streams stay
//! bit-identical to the old mutex server.
//!
//! API (full reference with curl examples: API.md; semantics: DESIGN.md
//! §14):
//!
//!   POST   /v1/sessions              {"cache_salt": 7 | "tenant" (opt)}
//!     -> {"session": 0, "cache_salt": "..."}
//!   POST   /v1/sessions/{id}/turns   {"tokens": [delta...],
//!                                     "adapter": "alora-0"|null,
//!                                     "max_new_tokens": 16,
//!                                     "append": true, "stream": false}
//!     -> turn summary JSON; with "stream": true -> chunked SSE events
//!        (`started`, `token`*, `finished`) whose token sequence is
//!        byte-identical to the non-streaming `tokens`
//!   POST   /v1/sessions/{id}/fork    {"count": 4 (opt, default 1),
//!                                     "adapters": [name|null, ...] (opt)}
//!     -> {"parent", "count", "children": [{"session", "adapter"}]} —
//!        K children sharing the parent's history and cached prefix
//!        (zero-copy refcount pins; DESIGN.md §18)
//!   GET    /v1/sessions              {"sessions": [ids], "count": n}
//!   GET    /v1/sessions/{id}         session document (history, turns)
//!   DELETE /v1/sessions/{id}         close + release the prefix lease
//!
//!   POST /generate   legacy one-shot (bit-identical response shape);
//!                    thin shim over the same submit/wait internals
//!   POST /pipeline   stage-graph spec (single or {"pipelines": [...]});
//!                    "stream": true on a single spec -> SSE
//!                    `stage_started` / `token` / `stage_finished` events
//!                    as stages generate and retire, then `done`
//!   GET  /metrics    Prometheus text exposition
//!   GET  /cluster    fleet stats JSON incl. per-replica health (single
//!                    engines report a one-replica document — never 404)
//!   GET  /cluster/health
//!                    failure-detector document: per-replica health state
//!                    machine, miss counters, silenced/warming flags
//!                    (404 on a single engine — no heartbeat surface)
//!   POST /cluster/replicas/{i}/{fail|drain|restore|silence}
//!                    replica administration (no body): fail evacuates +
//!                    requeues the replica's work onto survivors and
//!                    repairs affected sessions; drain excludes it from
//!                    new placements while it finishes; restore returns
//!                    it to rotation (cold after a failure) or lifts a
//!                    silence; silence injects a heartbeat fault (the
//!                    detector walks it Up -> Suspected -> Down)
//!   GET  /health     {"status": "ok"}
//!
//! Every error is a structured envelope with a meaningful status code:
//! `{"error": {"code": "...", "message": "..."}}` — `invalid_json`,
//! `missing_body`, `payload_too_large` (413), `unknown_adapter` (404),
//! `session_not_found` (404), `turn_in_flight` (409), `timeout` (504),
//! `invalid_request`, `not_found`.

pub mod v1;

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::adapter::AdapterRegistry;
use crate::coordinator::{spec, Coordinator};
use crate::engine::EngineDriver;
use crate::kvcache::hash::tenant_salt;
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams, TurnEvent};
use crate::session::SessionManager;
use crate::util::json::Json;

/// Bodies past this are refused with 413 before being read.
pub const MAX_BODY_BYTES: usize = 8 << 20;
/// Absolute per-request deadline, blocking and streaming paths alike
/// (virtual work is fast; this guards against stalls, not slow models).
pub(crate) const REQUEST_TIMEOUT: Duration = Duration::from_secs(60);

/// A unit of work for the driver thread: runs with exclusive access to the
/// engine plus the shared state. Commands are executed strictly FIFO and
/// never interleave with a step, which is what makes
/// submit-and-register atomic.
type Cmd<D> = Box<dyn FnOnce(&mut D, &Shared<D>) + Send>;

/// State shared between handler threads and the driver thread. Note what
/// is NOT here: the engine. It is owned by the driver thread; handlers
/// reach it only through the command queue.
pub(crate) struct Shared<D: EngineDriver> {
    /// MPSC submit queue, drained FIFO by the driver between steps.
    queue: Mutex<VecDeque<Cmd<D>>>,
    queue_cv: Condvar,
    /// Conversation state behind the v1 endpoints. Sharded internally, so
    /// handler threads read and abort directly without a driver
    /// round-trip.
    pub(crate) sessions: SessionManager,
    /// Sharded per-request delivery registry (wait slots, stream sinks,
    /// pipeline groups).
    pub(crate) waiters: WaiterTable,
    stop: AtomicBool,
}

impl<D: EngineDriver> Shared<D> {
    fn enqueue(&self, cmd: Cmd<D>) {
        self.queue.lock().unwrap().push_back(cmd);
        self.queue_cv.notify_all();
    }

    /// Run `f` on the driver thread — FIFO with every other command — and
    /// block until its result is back. The engine reference it receives
    /// is exclusive for the command's duration: no step, no other
    /// handler. Commands must never call `call` themselves (the driver
    /// would wait on itself).
    pub(crate) fn call<T, F>(&self, f: F) -> T
    where
        T: Send + 'static,
        F: FnOnce(&mut D, &Shared<D>) -> T + Send + 'static,
    {
        let slot: Arc<(Mutex<Option<T>>, Condvar)> = Arc::new((Mutex::new(None), Condvar::new()));
        let reply = Arc::clone(&slot);
        self.enqueue(Box::new(move |engine, shared| {
            let v = f(engine, shared);
            *reply.0.lock().unwrap() = Some(v);
            reply.1.notify_all();
        }));
        let mut g = slot.0.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return v;
            }
            g = slot.1.wait(g).unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// Sharded waiter/sink registry: how the driver hands outputs and events
// back to the handler threads that registered for them.

const WAITER_SHARDS: usize = 16;

/// How one wait for a single request ended.
pub(crate) enum WaitOutcome {
    Done(RequestOutput),
    /// Lost to a replica failure; the requeue was rejected on every
    /// survivor, so no output will ever come.
    Lost,
}

/// A one-shot rendezvous for a single blocking request.
pub(crate) struct WaitSlot {
    state: Mutex<Option<WaitOutcome>>,
    cv: Condvar,
}

impl WaitSlot {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(WaitSlot { state: Mutex::new(None), cv: Condvar::new() })
    }

    fn put(&self, v: WaitOutcome) {
        *self.state.lock().unwrap() = Some(v);
        self.cv.notify_all();
    }

    /// Absolute-deadline wait; `None` on timeout.
    pub(crate) fn wait(&self, deadline: Instant) -> Option<WaitOutcome> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return Some(v);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }
}

/// What one wake-up of a streaming wait produced.
pub(crate) enum SinkWait {
    Events(Vec<TurnEvent>),
    /// Failover tombstone: no more events will ever arrive.
    Lost,
    TimedOut,
}

/// A streaming turn's event channel: the driver pushes, the handler
/// drains and forwards as SSE.
pub(crate) struct StreamSink {
    state: Mutex<SinkState>,
    cv: Condvar,
}

#[derive(Default)]
struct SinkState {
    events: Vec<TurnEvent>,
    lost: bool,
}

impl StreamSink {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(StreamSink { state: Mutex::new(SinkState::default()), cv: Condvar::new() })
    }

    fn push(&self, ev: TurnEvent) {
        self.state.lock().unwrap().events.push(ev);
        self.cv.notify_all();
    }

    fn fail(&self) {
        self.state.lock().unwrap().lost = true;
        self.cv.notify_all();
    }

    pub(crate) fn wait(&self, deadline: Instant) -> SinkWait {
        let mut g = self.state.lock().unwrap();
        loop {
            if g.lost {
                return SinkWait::Lost;
            }
            if !g.events.is_empty() {
                return SinkWait::Events(std::mem::take(&mut g.events));
            }
            let now = Instant::now();
            if now >= deadline {
                return SinkWait::TimedOut;
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Disconnect cleanup: a `Finished` output still sitting undelivered
    /// in the sink.
    pub(crate) fn find_finished(&self) -> Option<RequestOutput> {
        let st = self.state.lock().unwrap();
        st.events.iter().find_map(|ev| match ev {
            TurnEvent::Finished { output, .. } => Some(output.clone()),
            _ => None,
        })
    }
}

/// What one wake-up of a pipeline wait produced.
enum GroupWait {
    /// Per-token events (streaming runs only — empty otherwise) plus
    /// newly retired stage outputs. Either vector may be empty, never
    /// both.
    Ready { events: Vec<TurnEvent>, outs: Vec<RequestOutput> },
    /// Stages lost to a replica failure (requeue rejected everywhere).
    Lost(Vec<RequestId>),
    TimedOut,
}

/// A pipeline run's completion channel: every stage request of the run
/// registers against the same group, so the handler wakes once per batch
/// of retirements instead of once per driver step. A streaming run
/// additionally watches its stage requests; their `started`/`token`
/// events ride the same channel.
pub(crate) struct PipeGroup {
    state: Mutex<GroupState>,
    cv: Condvar,
}

#[derive(Default)]
struct GroupState {
    events: Vec<TurnEvent>,
    ready: Vec<RequestOutput>,
    lost: Vec<RequestId>,
}

impl PipeGroup {
    fn new() -> Arc<Self> {
        Arc::new(PipeGroup { state: Mutex::new(GroupState::default()), cv: Condvar::new() })
    }

    fn push_done(&self, out: RequestOutput) {
        self.state.lock().unwrap().ready.push(out);
        self.cv.notify_all();
    }

    fn push_event(&self, ev: TurnEvent) {
        // The `Finished` copy is redundant here: the canonical output
        // arrives via `deliver` → `push_done`, which also drives the
        // coordinator's chaining. Buffering both would double-retire.
        if matches!(ev, TurnEvent::Finished { .. }) {
            return;
        }
        self.state.lock().unwrap().events.push(ev);
        self.cv.notify_all();
    }

    fn push_lost(&self, id: RequestId) {
        self.state.lock().unwrap().lost.push(id);
        self.cv.notify_all();
    }

    fn wait(&self, deadline: Instant) -> GroupWait {
        let mut g = self.state.lock().unwrap();
        loop {
            if !g.events.is_empty() || !g.ready.is_empty() {
                return GroupWait::Ready {
                    events: std::mem::take(&mut g.events),
                    outs: std::mem::take(&mut g.ready),
                };
            }
            if !g.lost.is_empty() {
                return GroupWait::Lost(std::mem::take(&mut g.lost));
            }
            let now = Instant::now();
            if now >= deadline {
                return GroupWait::TimedOut;
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Drop a delivered-but-unprocessed output (abandon path). True if
    /// the output was present.
    fn discard_ready(&self, id: RequestId) -> bool {
        let mut g = self.state.lock().unwrap();
        match g.ready.iter().position(|o| o.id == id) {
            Some(pos) => {
                g.ready.remove(pos);
                true
            }
            None => false,
        }
    }
}

/// What a registered request delivers into.
enum Entry {
    Waiter(Arc<WaitSlot>),
    Stream(Arc<StreamSink>),
    Group(Arc<PipeGroup>),
}

/// RequestId -> delivery entry, sharded 16 ways so concurrent handlers
/// registering/removing and the driver delivering rarely touch the same
/// lock. A request with NO entry delivers nowhere: removing an entry IS
/// the orphan operation (the driver drops the output on arrival), which
/// replaces the old server's `done`/`orphaned`/`failed` maps outright.
pub(crate) struct WaiterTable {
    shards: Vec<Mutex<HashMap<RequestId, Entry>>>,
}

impl WaiterTable {
    fn new() -> Self {
        WaiterTable {
            shards: (0..WAITER_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, id: RequestId) -> &Mutex<HashMap<RequestId, Entry>> {
        // Fleet request ids stripe by replica; mix the bits so shard
        // choice doesn't correlate with replica count.
        let h = id.0.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 56;
        &self.shards[h as usize % WAITER_SHARDS]
    }

    pub(crate) fn register_waiter(&self, id: RequestId, slot: Arc<WaitSlot>) {
        self.shard(id).lock().unwrap().insert(id, Entry::Waiter(slot));
    }

    pub(crate) fn register_stream(&self, id: RequestId, sink: Arc<StreamSink>) {
        self.shard(id).lock().unwrap().insert(id, Entry::Stream(sink));
    }

    /// Pipeline stages register if absent (roots once at setup, children
    /// as chaining submits them; stages already registered stay put).
    fn register_group(&self, id: RequestId, group: &Arc<PipeGroup>) {
        self.shard(id)
            .lock()
            .unwrap()
            .entry(id)
            .or_insert_with(|| Entry::Group(Arc::clone(group)));
    }

    /// Deregister. For a live request this is the orphan operation: its
    /// output (and events) are dropped on arrival.
    pub(crate) fn remove(&self, id: RequestId) {
        self.shard(id).lock().unwrap().remove(&id);
    }

    /// Route one finished output (driver thread). Waiter entries are
    /// consumed; stream entries keep delivering through their event sink
    /// (the output rides the `Finished` event); group entries stay until
    /// the handler's chaining command removes them, so a later abandon
    /// can tell delivered-unprocessed from still-running.
    fn deliver(&self, out: RequestOutput) {
        let mut shard = self.shard(out.id).lock().unwrap();
        if matches!(shard.get(&out.id), Some(Entry::Waiter(_))) {
            let Some(Entry::Waiter(slot)) = shard.remove(&out.id) else { unreachable!() };
            drop(shard);
            slot.put(WaitOutcome::Done(out));
            return;
        }
        if let Some(Entry::Group(g)) = shard.get(&out.id) {
            let g = Arc::clone(g);
            drop(shard);
            g.push_done(out);
        }
        // Stream entries keep delivering through their event sink (the
        // output rides the `Finished` event); no entry = orphaned or
        // never registered: drop the output.
    }

    /// Route one turn event (driver thread) into its stream sink or
    /// pipeline group, if the subscription is still registered.
    fn push_event(&self, ev: TurnEvent) {
        enum Target {
            Sink(Arc<StreamSink>),
            Group(Arc<PipeGroup>),
        }
        let target = {
            let shard = self.shard(ev.id()).lock().unwrap();
            match shard.get(&ev.id()) {
                Some(Entry::Stream(sink)) => Some(Target::Sink(Arc::clone(sink))),
                Some(Entry::Group(g)) => Some(Target::Group(Arc::clone(g))),
                _ => None, // abandoned between emission and drain: drop
            }
        };
        match target {
            Some(Target::Sink(sink)) => sink.push(ev),
            Some(Target::Group(g)) => g.push_event(ev),
            None => {}
        }
    }

    /// Failover tombstone: the request will NEVER produce an output, so
    /// whoever is waiting fails NOW instead of at the 60 s deadline.
    pub(crate) fn reject(&self, id: RequestId) {
        match self.shard(id).lock().unwrap().remove(&id) {
            Some(Entry::Waiter(slot)) => slot.put(WaitOutcome::Lost),
            Some(Entry::Stream(sink)) => sink.fail(),
            Some(Entry::Group(g)) => g.push_lost(id),
            None => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Structured error envelope (satellite): {"error": {"code", "message"}}.

#[derive(Debug)]
pub struct ApiError {
    pub status: &'static str,
    pub code: &'static str,
    pub message: String,
}

impl ApiError {
    pub fn new(status: &'static str, code: &'static str, message: impl Into<String>) -> Self {
        ApiError { status, code, message: message.into() }
    }

    pub fn bad_request(code: &'static str, message: impl Into<String>) -> Self {
        Self::new("400 Bad Request", code, message)
    }

    pub fn not_found(code: &'static str, message: impl Into<String>) -> Self {
        Self::new("404 Not Found", code, message)
    }

    pub fn conflict(code: &'static str, message: impl Into<String>) -> Self {
        Self::new("409 Conflict", code, message)
    }

    pub fn timeout(message: impl Into<String>) -> Self {
        Self::new("504 Gateway Timeout", "timeout", message)
    }

    /// The envelope body.
    pub fn body(&self) -> String {
        Json::obj(vec![("error", self.event_json())]).to_string()
    }

    /// The inner object (also the payload of a streaming `error` event).
    pub fn event_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("message", Json::str(self.message.clone())),
        ])
    }
}

/// Map engine/session errors onto the envelope. The lower layers speak
/// `anyhow` with stable message prefixes; this is the single place that
/// translates them into wire codes, so handlers never hand-classify.
pub(crate) fn classify(e: anyhow::Error) -> ApiError {
    let message = e.to_string();
    if message.contains("unknown adapter") {
        ApiError::not_found("unknown_adapter", message)
    } else if message.contains("unknown session") {
        ApiError::not_found("session_not_found", message)
    } else if message.contains("in flight") {
        ApiError::conflict("turn_in_flight", message)
    } else if message.contains("timed out") {
        ApiError::timeout(message)
    } else if message.starts_with("no replica ") {
        ApiError::not_found("replica_not_found", message)
    } else if message.contains("already down")
        || message.contains("already up")
        || message.contains("only an up replica")
        || message.contains("can be silenced")
        || message.contains("last healthy")
        || message.contains("no healthy survivor")
    {
        // Replica admin against the wrong current state (fail a dead
        // replica, drain the last one, ...): a state conflict, not a
        // malformed request.
        ApiError::conflict("replica_state", message)
    } else {
        ApiError::bad_request("invalid_request", message)
    }
}

/// Resolve an optional adapter name against the registry (404 envelope on
/// unknown names — the satellite's "correct status codes" contract).
pub(crate) fn resolve_target(
    registry: &AdapterRegistry,
    name: Option<&str>,
) -> Result<ModelTarget, ApiError> {
    match name {
        None => Ok(ModelTarget::Base),
        Some(n) => registry
            .by_name(n)
            .map(|a| ModelTarget::Adapter(a.id))
            .ok_or_else(|| ApiError::not_found("unknown_adapter", format!("unknown adapter `{n}`"))),
    }
}

// ---------------------------------------------------------------------------
// Server lifecycle.

/// A running server; `shutdown()` or drop stops the driver thread.
pub struct Server<D: EngineDriver + Send + 'static> {
    shared: Arc<Shared<D>>,
    addr: std::net::SocketAddr,
    listener_handle: Option<std::thread::JoinHandle<()>>,
    driver_handle: Option<std::thread::JoinHandle<()>>,
}

impl<D: EngineDriver + Send + 'static> Server<D> {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and start
    /// the driver + listener threads. `engine` is any [`EngineDriver`]:
    /// pass an [`crate::engine::Engine`] for single-replica serving or a
    /// [`crate::cluster::Cluster`] for routed fleet serving. The engine
    /// moves INTO the driver thread — nothing else ever touches it.
    pub fn start(engine: D, addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            sessions: SessionManager::new(),
            waiters: WaiterTable::new(),
            stop: AtomicBool::new(false),
        });

        // Driver thread: owns the engine. Loop = drain every queued
        // command FIFO, then (if there is work) one step, then route the
        // step's emissions into the waiter table. Commands therefore
        // never interleave with a step, and a single thread sequences
        // everything that touches the engine.
        let driver = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let mut engine = engine;
                loop {
                    if shared.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let cmds: Vec<Cmd<D>> = {
                        let mut q = shared.queue.lock().unwrap();
                        if q.is_empty() && !engine.has_work() {
                            // Idle: sleep until a submission lands (short
                            // timeout so shutdown is prompt).
                            let (guard, _) = shared
                                .queue_cv
                                .wait_timeout(q, Duration::from_millis(10))
                                .unwrap();
                            q = guard;
                        }
                        q.drain(..).collect()
                    };
                    for cmd in cmds {
                        cmd(&mut engine, &shared);
                    }
                    if engine.has_work() {
                        engine.step();
                        route_emissions(&mut engine, &shared);
                        repair_detected_failovers(&mut engine, &shared);
                    }
                }
                // Final drain: commands enqueued while we were breaking
                // still run, so no handler stays blocked on its reply.
                loop {
                    let cmds: Vec<Cmd<D>> =
                        shared.queue.lock().unwrap().drain(..).collect();
                    if cmds.is_empty() {
                        break;
                    }
                    for cmd in cmds {
                        cmd(&mut engine, &shared);
                    }
                }
            })
        };

        // Listener thread: accept + handle connections (one thread each).
        let listener_handle = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || loop {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let shared = Arc::clone(&shared);
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, &shared);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            })
        };

        Ok(Server {
            shared,
            addr: local,
            listener_handle: Some(listener_handle),
            driver_handle: Some(driver),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.queue_cv.notify_all();
        if let Some(h) = self.listener_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.driver_handle.take() {
            let _ = h.join();
        }
    }
}

impl<D: EngineDriver + Send + 'static> Drop for Server<D> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Route one step's emissions (driver thread): turn events into their
/// stream sinks, finished outputs into their wait slots / groups.
fn route_emissions<D: EngineDriver>(engine: &mut D, shared: &Shared<D>) {
    for ev in engine.take_events() {
        shared.waiters.push_event(ev);
    }
    for out in engine.take_finished() {
        shared.waiters.deliver(out);
    }
}

/// Failovers the fleet's failure detector declared during the step just
/// taken (DESIGN.md §19) get the SAME session repair an operator-declared
/// `POST /cluster/replicas/{i}/fail` gets — orphaned leases forgotten,
/// stranded sessions unstuck, rejected waiters failed now rather than at
/// their timeout. Runs on the driver thread right after the step, so no
/// command can observe stale stickiness in between.
fn repair_detected_failovers<D: EngineDriver>(engine: &mut D, shared: &Shared<D>) {
    for report in engine.take_failover_reports() {
        shared.sessions.repair_after_failover(engine, &report);
        for id in &report.rejected {
            shared.waiters.reject(*id);
        }
    }
}

// ---------------------------------------------------------------------------
// Connection handling.

/// What a routed request resolves to: a complete response, or a streaming
/// handler that owns the socket from here on.
enum Reply {
    Full { status: &'static str, ctype: &'static str, body: String },
    TurnStream { session: u64, turn: v1::TurnBody },
    PipelineStream { spec: Json },
}

fn full_ok(body: String) -> Reply {
    Reply::Full { status: "200 OK", ctype: "application/json", body }
}

fn full_err(e: ApiError) -> Reply {
    Reply::Full { status: e.status, ctype: "application/json", body: e.body() }
}

fn from_result(r: Result<Json, ApiError>) -> Reply {
    match r {
        Ok(j) => full_ok(j.to_string()),
        Err(e) => full_err(e),
    }
}

pub(crate) fn write_response(
    stream: &mut TcpStream,
    status: &str,
    ctype: &str,
    content: &str,
) -> anyhow::Result<()> {
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        content.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(content.as_bytes())?;
    Ok(())
}

// -- HTTP/1.1 chunked SSE plumbing (streaming turns & pipelines) ------------

pub(crate) fn start_stream(stream: &mut TcpStream) -> anyhow::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nTransfer-Encoding: chunked\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    Ok(())
}

fn write_chunk(stream: &mut TcpStream, payload: &str) -> anyhow::Result<()> {
    stream.write_all(format!("{:x}\r\n", payload.len()).as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.write_all(b"\r\n")?;
    Ok(())
}

/// One SSE event as one chunk: `event: <name>\ndata: <json>\n\n`.
pub(crate) fn write_sse(stream: &mut TcpStream, event: &str, data: &Json) -> anyhow::Result<()> {
    write_chunk(stream, &format!("event: {event}\ndata: {data}\n\n"))
}

/// Terminal zero-length chunk.
pub(crate) fn end_stream(stream: &mut TcpStream) -> anyhow::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    Ok(())
}

fn handle_conn<D: EngineDriver>(mut stream: TcpStream, shared: &Shared<D>) -> anyhow::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    if content_len > MAX_BODY_BYTES {
        // Refuse before reading: an oversized body never enters memory.
        let e = ApiError::new(
            "413 Payload Too Large",
            "payload_too_large",
            format!("body of {content_len} bytes exceeds the {MAX_BODY_BYTES}-byte limit"),
        );
        return write_response(&mut stream, e.status, "application/json", &e.body());
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }

    match route(&method, &path, &body, shared) {
        Reply::Full { status, ctype, body } => write_response(&mut stream, status, ctype, &body),
        Reply::TurnStream { session, turn } => v1::stream_turn(&mut stream, shared, session, turn),
        Reply::PipelineStream { spec } => stream_pipeline(&mut stream, shared, &spec),
    }
}

fn route<D: EngineDriver>(method: &str, path: &str, body: &[u8], shared: &Shared<D>) -> Reply {
    match method {
        "GET" => match path {
            "/health" => Reply::Full {
                status: "200 OK",
                ctype: "application/json",
                body: r#"{"status":"ok"}"#.into(),
            },
            "/metrics" => Reply::Full {
                status: "200 OK",
                ctype: "text/plain; version=0.0.4",
                body: shared.call(|engine, _| engine.render_prometheus()),
            },
            "/cluster" => {
                let stats =
                    shared.call(|engine, _| engine.cluster_stats().map(|cs| cs.to_json().to_string()));
                match stats {
                    Some(body) => full_ok(body),
                    // Unreachable for the in-tree drivers (a single engine
                    // reports a one-replica document), kept for third-party
                    // EngineDriver impls without stats.
                    None => full_err(ApiError::not_found(
                        "not_found",
                        "this driver exposes no fleet stats",
                    )),
                }
            }
            "/cluster/health" => {
                let doc = shared.call(|engine, _| engine.cluster_health().map(|j| j.to_string()));
                match doc {
                    Some(body) => full_ok(body),
                    // Single engines have no heartbeat surface — unlike
                    // `GET /cluster` there is no one-replica equivalent.
                    None => full_err(ApiError::not_found(
                        "not_found",
                        "health detection needs a multi-replica cluster",
                    )),
                }
            }
            "/v1/sessions" => from_result(v1::list_sessions(shared)),
            p => match parse_session_path(p) {
                Some((sid, SessionRoute::Root)) => from_result(v1::get_session(shared, sid)),
                _ => full_err(ApiError::not_found("not_found", format!("no route for GET {p}"))),
            },
        },
        "POST" => {
            // Replica administration takes no body — route it before the
            // body requirement.
            if let Some((i, action)) = parse_replica_action(path) {
                return from_result(replica_action(shared, i, action));
            }
            if path.starts_with("/cluster/replicas/") {
                return full_err(ApiError::not_found(
                    "not_found",
                    format!("no route for POST {path} (actions: fail, drain, restore, silence)"),
                ));
            }
            if body.is_empty() {
                return full_err(ApiError::bad_request(
                    "missing_body",
                    "POST endpoints require a JSON body",
                ));
            }
            let j = match std::str::from_utf8(body).map_err(|e| e.to_string()).and_then(
                |text| Json::parse(text).map_err(|e| e.to_string()),
            ) {
                Ok(j) => j,
                Err(e) => return full_err(ApiError::bad_request("invalid_json", e)),
            };
            match path {
                "/generate" => from_result(generate(&j, shared)),
                "/pipeline" => {
                    if j.get("stream").and_then(Json::as_bool).unwrap_or(false) {
                        if j.get("pipelines").is_some() {
                            return full_err(ApiError::bad_request(
                                "invalid_request",
                                "streaming supports a single spec, not a `pipelines` batch",
                            ));
                        }
                        return Reply::PipelineStream { spec: j };
                    }
                    from_result(run_pipeline(&j, shared).map_err(classify))
                }
                "/v1/sessions" => from_result(v1::create_session(&j, shared)),
                p => match parse_session_path(p) {
                    Some((sid, SessionRoute::Turns)) => match v1::parse_turn(&j) {
                        Err(e) => full_err(e),
                        Ok(turn) if turn.stream => Reply::TurnStream { session: sid, turn },
                        Ok(turn) => from_result(v1::run_turn(shared, sid, turn)),
                    },
                    Some((sid, SessionRoute::Fork)) => {
                        from_result(v1::fork_session(&j, shared, sid))
                    }
                    _ => full_err(ApiError::not_found(
                        "not_found",
                        format!("no route for POST {p}"),
                    )),
                },
            }
        }
        "DELETE" => match parse_session_path(path) {
            Some((sid, SessionRoute::Root)) => from_result(v1::delete_session(shared, sid)),
            _ => full_err(ApiError::not_found(
                "not_found",
                format!("no route for DELETE {path}"),
            )),
        },
        m => full_err(ApiError::not_found("not_found", format!("no route for {m} {path}"))),
    }
}

/// Parse `/cluster/replicas/{i}/{fail|drain|restore|silence}` admin paths.
fn parse_replica_action(path: &str) -> Option<(usize, &str)> {
    let rest = path.strip_prefix("/cluster/replicas/")?;
    let mut parts = rest.split('/');
    let i: usize = parts.next()?.parse().ok()?;
    let action = parts.next()?;
    if parts.next().is_some() || !matches!(action, "fail" | "drain" | "restore" | "silence") {
        return None;
    }
    Some((i, action))
}

/// Replica administration (`POST /cluster/replicas/{i}/{fail|drain|restore|silence}`).
/// `fail` additionally repairs the session layer — orphaned leases are
/// forgotten, stranded conversations lose their stickiness peer (they
/// re-stick on their next turn), and turns whose requeue was rejected are
/// aborted. Runs as one driver command, so the evacuation, the session
/// repair, and the waiter tombstones are atomic with respect to steps.
fn replica_action<D: EngineDriver>(
    shared: &Shared<D>,
    i: usize,
    action: &str,
) -> Result<Json, ApiError> {
    match action {
        "fail" => shared.call(move |engine, sh| {
            let report = match engine.fail_replica(i) {
                Ok(r) => r,
                Err(e) => return Err(classify(e)),
            };
            let (leases_dropped, resticks_pending, turns_aborted) =
                sh.sessions.repair_after_failover(&mut *engine, &report);
            // Requests no survivor accepted will never finish: fail their
            // blocked waiters NOW, not at the 60 s deadline.
            for id in &report.rejected {
                sh.waiters.reject(*id);
            }
            Ok(Json::obj(vec![
                ("replica", Json::num(i as f64)),
                ("health", Json::str("down")),
                ("requeued", Json::num(report.requeued as f64)),
                ("rejected", Json::num(report.rejected.len() as f64)),
                (
                    "orphaned_leases",
                    Json::num(report.orphaned_leases.len() as f64),
                ),
                ("sessions_leases_dropped", Json::num(leases_dropped as f64)),
                ("sessions_unstuck", Json::num(resticks_pending as f64)),
                ("turns_aborted", Json::num(turns_aborted as f64)),
            ]))
        }),
        "drain" => shared.call(move |engine, _| match engine.drain_replica(i) {
            Err(e) => Err(classify(e)),
            Ok(()) => Ok(Json::obj(vec![
                ("replica", Json::num(i as f64)),
                ("health", Json::str("draining")),
            ])),
        }),
        "restore" => shared.call(move |engine, _| match engine.restore_replica(i) {
            Err(e) => Err(classify(e)),
            Ok(()) => Ok(Json::obj(vec![
                ("replica", Json::num(i as f64)),
                ("health", Json::str("up")),
            ])),
        }),
        // Fault injection (DESIGN.md §19): the replica stops heartbeating
        // while keeping its state and its work; the failure detector walks
        // it Up → Suspected → Down unless `restore` lifts the silence.
        "silence" => shared.call(move |engine, _| match engine.silence_replica(i) {
            Err(e) => Err(classify(e)),
            Ok(()) => Ok(Json::obj(vec![
                ("replica", Json::num(i as f64)),
                ("silenced", Json::Bool(true)),
            ])),
        }),
        _ => unreachable!("parse_replica_action filtered"),
    }
}

/// The sub-resource a `/v1/sessions/{id}[/...]` path addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SessionRoute {
    /// `/v1/sessions/{id}` — the session document itself.
    Root,
    /// `/v1/sessions/{id}/turns` — submit a delta turn.
    Turns,
    /// `/v1/sessions/{id}/fork` — fork K prefix-sharing children.
    Fork,
}

/// Parse `/v1/sessions/{id}`, `/v1/sessions/{id}/turns` and
/// `/v1/sessions/{id}/fork` paths. None for anything else.
fn parse_session_path(path: &str) -> Option<(u64, SessionRoute)> {
    let rest = path.strip_prefix("/v1/sessions/")?;
    let mut parts = rest.split('/');
    let id: u64 = parts.next()?.parse().ok()?;
    let route = match parts.next() {
        None => return Some((id, SessionRoute::Root)),
        Some("turns") => SessionRoute::Turns,
        Some("fork") => SessionRoute::Fork,
        _ => return None,
    };
    if parts.next().is_some() {
        return None;
    }
    Some((id, route))
}

/// Parse the optional multi-tenant `cache_salt` field: a raw u64, or a
/// tenant-name string hashed to a stable nonzero salt.
pub(crate) fn parse_cache_salt(req: &Json) -> anyhow::Result<u64> {
    match req.get("cache_salt") {
        None | Some(Json::Null) => Ok(0),
        Some(v) => {
            if let Some(n) = v.as_u64() {
                Ok(n)
            } else if let Some(s) = v.as_str() {
                Ok(tenant_salt(s))
            } else {
                anyhow::bail!("`cache_salt` must be an integer or a tenant string")
            }
        }
    }
}

/// Block on a request's wait slot with the absolute deadline. Shared by
/// `/generate` and non-streaming turns — the legacy endpoint is a shim
/// over the same wait the v1 path uses.
pub(crate) fn wait_done<D: EngineDriver>(
    shared: &Shared<D>,
    id: RequestId,
    slot: &WaitSlot,
) -> Result<RequestOutput, ApiError> {
    match slot.wait(Instant::now() + REQUEST_TIMEOUT) {
        Some(WaitOutcome::Done(out)) => Ok(out),
        Some(WaitOutcome::Lost) => Err(ApiError::new(
            "502 Bad Gateway",
            "request_failed",
            format!("request {id:?} was lost to a replica failure and could not be requeued"),
        )),
        None => {
            // Abandon the request: deregistering makes the driver drop
            // its output on arrival instead of parking it forever.
            shared.waiters.remove(id);
            Err(ApiError::timeout(format!("request {id:?} timed out")))
        }
    }
}

// ---------------------------------------------------------------------------
// Legacy endpoints (thin shims over the shared internals; success
// responses are bit-identical to the pre-v1 server).

/// The legacy `/generate` wire shape — exact field set and ordering
/// (object keys serialize sorted), pinned by tests.
fn generate_response(out: &RequestOutput) -> Json {
    Json::obj(vec![
        ("id", Json::num(out.id.0 as f64)),
        (
            "tokens",
            Json::Arr(out.output_tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("e2e_s", Json::num(out.timeline.e2e())),
        ("ttft_s", Json::num(out.timeline.ttft())),
        ("itl_s", Json::num(out.itl())),
        ("cache_hit_rate", Json::num(out.cache_hit_rate())),
        ("preemptions", Json::num(out.preemptions as f64)),
    ])
}

fn generate<D: EngineDriver>(j: &Json, shared: &Shared<D>) -> Result<Json, ApiError> {
    let prompt = j.get("prompt").and_then(Json::u32_vec).ok_or_else(|| {
        ApiError::bad_request("invalid_request", "`prompt` must be an array of token ids")
    })?;
    let max_new = j.get("max_new_tokens").and_then(Json::as_u64).unwrap_or(16) as u32;
    let adapter_name = j.get("adapter").and_then(Json::as_str).map(str::to_string);
    let cache_salt = parse_cache_salt(j).map_err(classify)?;

    let slot = WaitSlot::new();
    let submitted = {
        let slot = Arc::clone(&slot);
        shared.call(move |engine, sh| {
            let target = match resolve_target(engine.registry(), adapter_name.as_deref()) {
                Ok(t) => t,
                Err(e) => return Err(e),
            };
            let id = match engine.submit_salted(
                target,
                prompt,
                SamplingParams { max_new_tokens: max_new, ..Default::default() },
                false,
                cache_salt,
            ) {
                Ok(id) => id,
                Err(e) => return Err(classify(e)),
            };
            // Registered in the same command as the submission: the
            // driver cannot step in between, so the output cannot slip
            // past the slot.
            sh.waiters.register_waiter(id, slot);
            Ok(id)
        })
    };
    let id = submitted?;
    wait_done(shared, id, &slot).map(|out| generate_response(&out))
}

// ---------------------------------------------------------------------------
// Pipelines over the command queue.

/// What the pipeline setup command hands back to its handler.
struct PipeSetup {
    co: Coordinator,
    /// Per input spec: the conversation index it became, or its error.
    convs: Vec<Result<usize, String>>,
    batched: bool,
    n_stages: usize,
    t0: f64,
}

/// What one chaining command hands back: the coordinator makes a round
/// trip through the driver thread (it is plain data — the handler owns it
/// between commands).
struct ChainOutcome {
    co: Coordinator,
    convs: Vec<Result<usize, String>>,
    failed: Option<anyhow::Error>,
}

/// Orphan every in-flight stage of an abandoned coordinator run: drop
/// outputs already delivered to the group and deregister the rest so the
/// driver discards them on arrival. The single cleanup used by every
/// /pipeline abort path. Safe from the handler thread — both structures
/// take their own locks.
fn orphan_run<D: EngineDriver>(shared: &Shared<D>, group: &PipeGroup, co: &Coordinator) {
    for id in co.in_flight_ids() {
        shared.waiters.remove(id);
        group.discard_ready(id);
    }
}

/// Abandon one batch-`/pipeline` conversation after a submission failure:
/// deregister its in-flight stages (the driver discards their outputs),
/// drop anything already delivered, and record the per-entry error in
/// input order. Shared by the root-submission and chain-time failure
/// paths so their bookkeeping cannot diverge.
fn abandon_batch_entry<D: EngineDriver>(
    co: &mut Coordinator,
    sh: &Shared<D>,
    group: &PipeGroup,
    convs: &mut [Result<usize, String>],
    ci: usize,
    err: String,
) {
    for id in co.abandon_conversation(ci) {
        sh.waiters.remove(id);
        group.discard_ready(id);
    }
    if let Some(idx) = convs.iter().position(|c| c.as_ref().ok() == Some(&ci)) {
        convs[idx] = Err(err);
    }
}

/// The pipeline setup command: parse, build the coordinator, submit every
/// root, and register the surviving in-flight stages with the run's
/// group. Runs as ONE driver command, so registration is atomic with
/// submission.
fn pipeline_setup<D: EngineDriver>(
    engine: &mut D,
    sh: &Shared<D>,
    spec_json: &Json,
    group: &Arc<PipeGroup>,
) -> anyhow::Result<PipeSetup> {
    let (specs, batched): (Vec<&Json>, bool) = match spec_json.get("pipelines") {
        Some(pj) => {
            let arr = pj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("`pipelines` must be an array of specs"))?;
            anyhow::ensure!(!arr.is_empty(), "`pipelines` is empty");
            (arr.iter().collect(), true)
        }
        None => (vec![spec_json], false),
    };
    let mut co = Coordinator::new();
    let mut convs: Vec<Result<usize, String>> = Vec::new();
    for &sj in &specs {
        let parsed = spec::graph_from_json(sj, engine.registry())
            .and_then(|g| co.add_conversation(g));
        convs.push(parsed.map_err(|e| e.to_string()));
    }
    if !batched {
        // Single-spec form keeps its contract: invalid spec = 400.
        if let Err(e) = &convs[0] {
            anyhow::bail!("{e}");
        }
    }
    let n_stages: usize = convs.iter().flatten().map(|&ci| co.graph(ci).len()).sum();
    let t0 = engine.clock();
    for idx in 0..convs.len() {
        let Ok(&ci) = convs[idx].as_ref() else { continue };
        if let Err(e) = co.submit_ready(&mut *engine, ci) {
            if batched {
                // Isolate the failing graph: abandon it (its partially
                // submitted roots keep running; their outputs get
                // discarded) and report it per-entry — a runtime reject
                // in one graph must not fail the rest of the batch.
                abandon_batch_entry(&mut co, sh, group, &mut convs, ci, e.to_string());
            } else {
                // Partially submitted roots were never registered: their
                // outputs are dropped on arrival.
                return Err(e);
            }
        }
    }
    for id in co.in_flight_ids() {
        sh.waiters.register_group(id, group);
    }
    Ok(PipeSetup { co, convs, batched, n_stages, t0 })
}

/// One chaining command: consume a batch of delivered outputs, let the
/// coordinator submit children the moment their parents retire, and
/// register the new in-flight stages — all atomic with respect to steps.
fn pipeline_chain<D: EngineDriver>(
    engine: &mut D,
    sh: &Shared<D>,
    mut co: Coordinator,
    mut convs: Vec<Result<usize, String>>,
    batched: bool,
    group: &Arc<PipeGroup>,
    outs: Vec<RequestOutput>,
) -> ChainOutcome {
    let mut failed: Option<anyhow::Error> = None;
    for out in outs {
        sh.waiters.remove(out.id);
        // An abandonment earlier in this drain may have already disowned
        // a sibling stage's output.
        if !co.owns(out.id) {
            continue;
        }
        let ci = co.conversation_of(out.id);
        if let Err(e) = co.on_finished(&mut *engine, out) {
            // Child-stage submission can fail at chaining time (e.g. a
            // composed prompt outgrowing max_seq_len). In batch mode that
            // conversation alone is abandoned and reported per-entry,
            // same as a root-submission failure.
            match ci {
                Some(ci) if batched => {
                    abandon_batch_entry(&mut co, sh, group, &mut convs, ci, e.to_string());
                }
                _ => {
                    failed = Some(e);
                    break;
                }
            }
        }
    }
    if failed.is_none() {
        for id in co.in_flight_ids() {
            sh.waiters.register_group(id, group);
        }
    }
    ChainOutcome { co, convs, failed }
}

/// Drive one or many stage-graph conversations to completion over the
/// shared engine. The driver thread does the stepping; this handler
/// blocks on the run's [`PipeGroup`] and issues one chaining command per
/// batch of retirements.
///
/// Batch form (`{"pipelines": [spec, ...]}`): every parseable graph runs;
/// graphs that fail validation — or whose submission the engine rejects
/// at runtime (e.g. a stage exceeding max_seq_len) — get a per-entry
/// `error` in the response instead of failing the whole request (a 400
/// is reserved for structural problems — non-array `pipelines`, empty
/// batch, unparseable body).
fn run_pipeline<D: EngineDriver>(spec_json: &Json, shared: &Shared<D>) -> anyhow::Result<Json> {
    let group = PipeGroup::new();
    let setup = {
        let spec = spec_json.clone();
        let group = Arc::clone(&group);
        shared.call(move |engine, sh| pipeline_setup(engine, sh, &spec, &group))
    };
    let PipeSetup { mut co, mut convs, batched, n_stages, t0 } = setup?;
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    let mut outcome: Option<anyhow::Error> = None;
    while outcome.is_none() && !co.is_done() {
        match group.wait(deadline) {
            // Non-streaming runs never watch their stage requests, so
            // `events` is always empty here.
            GroupWait::Ready { outs, .. } => {
                let g = Arc::clone(&group);
                let step = shared
                    .call(move |engine, sh| pipeline_chain(engine, sh, co, convs, batched, &g, outs));
                co = step.co;
                convs = step.convs;
                outcome = step.failed;
            }
            GroupWait::Lost(lost) => {
                // A stage lost to a replica failure (requeue rejected)
                // will never retire: fail the conversation now, not at
                // deadline.
                outcome = Some(anyhow::anyhow!(
                    "pipeline stage request {lost:?} was lost to a replica failure"
                ));
            }
            GroupWait::TimedOut => {
                outcome = Some(anyhow::anyhow!(
                    "pipeline timed out with {} of {n_stages} stages unfinished",
                    co.in_flight()
                ));
            }
        }
    }

    match outcome {
        None => {
            let makespan = shared.call(|engine, _| engine.clock()) - t0;
            let result = co.into_result(makespan);
            if batched {
                Ok(spec::batch_result_to_json(&result, &convs))
            } else {
                Ok(spec::result_to_json(&result))
            }
        }
        Some(e) => {
            orphan_run(shared, &group, &co);
            Err(e)
        }
    }
}

/// What one wake-up of a streaming wait produced.
enum StreamStep {
    /// Per-token events labeled with their stage name, newly retired
    /// stage JSONs, whether the run completed, makespan.
    Emit(Vec<(String, TurnEvent)>, Vec<Json>, bool, f64),
    Fail(ApiError),
}

/// The single-conversation chaining command used by the streaming path.
/// Returns (coordinator, failure, clock). Freshly submitted downstream
/// stages are watched so their `started`/`token` events ride the group;
/// finished ones unwatch themselves when the engine emits `Finished`.
fn pipeline_stream_chain<D: EngineDriver>(
    engine: &mut D,
    sh: &Shared<D>,
    mut co: Coordinator,
    group: &Arc<PipeGroup>,
    outs: Vec<RequestOutput>,
) -> (Coordinator, Option<anyhow::Error>, f64) {
    let mut failed: Option<anyhow::Error> = None;
    for out in outs {
        sh.waiters.remove(out.id);
        if !co.owns(out.id) {
            continue;
        }
        if let Err(e) = co.on_finished(&mut *engine, out) {
            failed = Some(e);
            break;
        }
    }
    if failed.is_none() {
        for id in co.in_flight_ids() {
            sh.waiters.register_group(id, group);
            engine.watch(id);
        }
    }
    let clock = engine.clock();
    (co, failed, clock)
}

/// Streaming-path orphan: drop group registrations AND cancel the event
/// subscriptions of every in-flight stage (non-streaming runs never
/// watch, so plain [`orphan_run`] suffices there).
fn orphan_stream_run<D: EngineDriver>(
    shared: &Shared<D>,
    group: &Arc<PipeGroup>,
    co: &Coordinator,
) {
    orphan_run(shared, group, co);
    let ids = co.in_flight_ids();
    shared.call(move |engine, _| {
        for id in ids {
            engine.unwatch(id);
        }
    });
}

/// Streaming `/pipeline` (single spec): per-token SSE emission through
/// the coordinator's completion stream — `stage_started` the moment a
/// stage is scheduled, `token` per generated token, `stage_finished`
/// when it retires (ROADMAP "streaming per-stage results over HTTP"),
/// then `done` with the makespan.
fn stream_pipeline<D: EngineDriver>(
    stream: &mut TcpStream,
    shared: &Shared<D>,
    spec_json: &Json,
) -> anyhow::Result<()> {
    let group = PipeGroup::new();
    let setup = {
        let spec = spec_json.clone();
        let group = Arc::clone(&group);
        shared.call(move |engine, sh| {
            let mut co = Coordinator::new();
            let submitted = spec::graph_from_json(&spec, engine.registry())
                .and_then(|graph| co.add_conversation(graph))
                .and_then(|ci| co.submit_ready(&mut *engine, ci));
            match submitted {
                Ok(_) => {
                    for id in co.in_flight_ids() {
                        sh.waiters.register_group(id, &group);
                        engine.watch(id);
                    }
                    Ok((co, engine.clock()))
                }
                // Nothing registered: any partially submitted root's
                // output is dropped on arrival.
                Err(e) => Err(classify(e)),
            }
        })
    };
    let (mut co, t0) = match setup {
        Ok(v) => v,
        // Nothing streamed yet: plain error response.
        Err(err) => return write_response(stream, err.status, "application/json", &err.body()),
    };
    let result = stream_pipeline_events(stream, shared, &group, &mut co, t0);
    if result.is_err() {
        // A socket write failed mid-stream (client went away): orphan the
        // coordinator's in-flight stages so the driver discards their
        // outputs instead of leaking them, and drop their event
        // subscriptions.
        orphan_stream_run(shared, &group, &co);
    }
    result
}

/// The emission phase of a streaming pipeline. Any `Err` here is a dead
/// client socket — `stream_pipeline` orphans the leftovers; engine-side
/// failures are reported in-band as `error` events (with their own
/// orphan handling before the event is written).
fn stream_pipeline_events<D: EngineDriver>(
    stream: &mut TcpStream,
    shared: &Shared<D>,
    group: &Arc<PipeGroup>,
    co: &mut Coordinator,
    t0: f64,
) -> anyhow::Result<()> {
    start_stream(stream)?;
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    let mut emitted = 0usize;
    loop {
        let step = match group.wait(deadline) {
            GroupWait::Ready { events, outs } => {
                // Label events BEFORE chaining: the chaining command
                // retires finished stages from the coordinator's owner
                // map, and with it the id → stage-name association.
                let labeled: Vec<(String, TurnEvent)> = events
                    .into_iter()
                    .filter_map(|ev| {
                        co.stage_name_of(ev.id()).map(|n| (n.to_string(), ev))
                    })
                    .collect();
                let owned = std::mem::replace(co, Coordinator::new());
                let g = Arc::clone(group);
                let (owned, failed, clock) = shared
                    .call(move |engine, sh| pipeline_stream_chain(engine, sh, owned, &g, outs));
                *co = owned;
                match failed {
                    Some(e) => {
                        orphan_stream_run(shared, group, co);
                        StreamStep::Fail(classify(e))
                    }
                    None => {
                        let new: Vec<Json> = co
                            .finished_since(emitted)
                            .iter()
                            .map(spec::stage_output_to_json)
                            .collect();
                        emitted = co.finished_stages().len();
                        StreamStep::Emit(labeled, new, co.is_done(), clock - t0)
                    }
                }
            }
            GroupWait::Lost(lost) => {
                // A stage lost to a replica failure never retires: fail
                // the stream now instead of at the deadline.
                orphan_stream_run(shared, group, co);
                StreamStep::Fail(ApiError::new(
                    "502 Bad Gateway",
                    "request_failed",
                    format!("pipeline stage request {lost:?} was lost to a replica failure"),
                ))
            }
            GroupWait::TimedOut => {
                orphan_stream_run(shared, group, co);
                StreamStep::Fail(ApiError::timeout(format!(
                    "pipeline timed out with {} stages in flight",
                    co.in_flight()
                )))
            }
        };
        match step {
            StreamStep::Fail(e) => {
                write_sse(stream, "error", &e.event_json())?;
                return end_stream(stream);
            }
            StreamStep::Emit(labeled, new, done, makespan) => {
                for (stage, ev) in &labeled {
                    match ev {
                        TurnEvent::Started { id, clock, arrival } => write_sse(
                            stream,
                            "stage_started",
                            &Json::obj(vec![
                                ("stage", Json::str(stage.as_str())),
                                ("id", Json::num(id.0 as f64)),
                                ("t_s", Json::num(*clock)),
                                ("queue_s", Json::num(clock - arrival)),
                            ]),
                        )?,
                        TurnEvent::Token { index, token, clock, .. } => write_sse(
                            stream,
                            "token",
                            &Json::obj(vec![
                                ("stage", Json::str(stage.as_str())),
                                ("index", Json::num(*index as f64)),
                                ("token", Json::num(*token as f64)),
                                ("t_s", Json::num(*clock)),
                            ]),
                        )?,
                        // `Finished` never reaches the group buffer.
                        TurnEvent::Finished { .. } => {}
                    }
                }
                for j in &new {
                    write_sse(stream, "stage_finished", j)?;
                }
                if done {
                    write_sse(
                        stream,
                        "done",
                        &Json::obj(vec![("makespan_s", Json::num(makespan))]),
                    )?;
                    return end_stream(stream);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, RoutePolicy};
    use crate::config::presets;
    use crate::engine::Engine;
    use crate::pipeline::workload;
    use crate::simulator::SimExecutor;

    fn sim_engine() -> Engine<SimExecutor> {
        let cfg = presets::granite_8b();
        let reg = workload::build_registry(2, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    }

    fn start_sim_server() -> Server<Engine<SimExecutor>> {
        Server::start(sim_engine(), "127.0.0.1:0").unwrap()
    }

    fn start_cluster_server(n: usize) -> Server<Cluster<SimExecutor>> {
        let cluster =
            Cluster::from_factory(n, RoutePolicy::PrefixAffinity, |_| sim_engine()).unwrap();
        Server::start(cluster, "127.0.0.1:0").unwrap()
    }

    fn http(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
        http(
            addr,
            &format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    /// Last line of an HTTP response = the JSON body (Content-Length
    /// framing, single-line JSON).
    fn body_json(resp: &str) -> Json {
        Json::parse(resp.lines().last().unwrap()).unwrap()
    }

    #[test]
    fn health_and_metrics_endpoints() {
        let mut srv = start_sim_server();
        let r = http(srv.addr(), "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""));
        let r = http(srv.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("alora_serve_requests_received_total"));
        srv.shutdown();
    }

    #[test]
    fn generate_roundtrip_base_and_adapter() {
        let mut srv = start_sim_server();
        let r = post(srv.addr(), "/generate", r#"{"prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 4}"#);
        assert!(r.contains("200 OK"), "{r}");
        assert!(r.contains("\"tokens\""));

        let r = post(
            srv.addr(),
            "/generate",
            r#"{"prompt": [1,2,3,4], "adapter": "alora-1", "max_new_tokens": 2}"#,
        );
        assert!(r.contains("200 OK"), "{r}");
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_runs_stage_graph() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..256).map(|t| (t % 4000).to_string()).collect();
        let body = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 32, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 8, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}],
                  "priority": true}},
                {{"name": "final", "gen": 8,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}},
                             {{"output_of": "check"}}]}}
            ]}}"#,
            p = prompt.join(",")
        );
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let stages = j.get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(stages.len(), 3);
        // downstream stages reuse upstream KV over HTTP too
        for s in stages {
            let name = s.get("name").and_then(Json::as_str).unwrap();
            let hit = s.get("cache_hit_rate").and_then(Json::as_f64).unwrap();
            if name != "draft" {
                assert!(hit > 0.5, "{name}: hit {hit}");
            }
        }
        assert!(j.get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_rejects_bad_spec() {
        let mut srv = start_sim_server();
        for body in [
            r#"{"stages": []}"#,
            r#"{"stages": [{"name": "a", "prompt": [{"output_of": "ghost"}]}]}"#,
        ] {
            let r = post(srv.addr(), "/pipeline", body);
            assert!(r.contains("400"), "{r}");
            assert!(r.contains("\"code\":\"invalid_request\""), "{r}");
        }
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_batches_graphs_with_per_graph_errors() {
        let mut srv = start_sim_server();
        let p: Vec<String> = (0..64).map(|t| (t % 4000).to_string()).collect();
        let good = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 8, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 4, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}}
            ]}}"#,
            p = p.join(",")
        );
        let bad = r#"{"stages": [{"name": "x", "prompt": [{"output_of": "ghost"}]}]}"#;
        let body = format!(r#"{{"pipelines": [{good}, {bad}, {good}]}}"#);
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps.len(), 3);
        for idx in [0usize, 2] {
            let stages = ps[idx].get("stages").and_then(Json::as_arr).unwrap();
            assert_eq!(stages.len(), 2, "pipeline {idx}");
            assert!(ps[idx].get("error").is_none());
        }
        assert!(ps[1].get("error").and_then(Json::as_str).unwrap().contains("ghost"));
        // A graph that passes validation but is rejected by the engine at
        // submission (gen beyond max_seq_len) is isolated the same way.
        let runtime_bad =
            r#"{"stages": [{"name": "x", "gen": 200000, "prompt": [[1,2,3]]}]}"#;
        let body = format!(r#"{{"pipelines": [{good}, {runtime_bad}]}}"#);
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps[0].get("stages").and_then(Json::as_arr).unwrap().len(), 2);
        assert!(ps[1]
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("max_seq_len"));
        // structural problems still 400
        for body in [r#"{"pipelines": []}"#, r#"{"pipelines": 5}"#] {
            assert!(post(srv.addr(), "/pipeline", body).contains("400"));
        }
        srv.shutdown();
    }

    #[test]
    fn pipeline_batch_isolates_child_stage_submit_failure() {
        // tiny preset: max_seq_len 160 — a child whose composed prompt
        // outgrows it is rejected only at CHAINING time, after its root
        // already ran. The batch must still return the good graph's
        // results with a per-entry error for the bad one.
        let cfg = presets::tiny();
        let reg = crate::adapter::AdapterRegistry::tiny_default(2, 512, 4);
        let exec = SimExecutor::new(&cfg);
        let mut srv =
            Server::start(Engine::with_registry(cfg, reg, exec), "127.0.0.1:0").unwrap();
        let good = r#"{"stages": [{"name": "a", "gen": 8, "prompt": [[1,2,3,4,5,6,7,8]]}]}"#;
        let p64: Vec<String> = (0..64).map(|t| (t % 400).to_string()).collect();
        let bad = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 32, "prompt": [[{p}]]}},
                {{"name": "kid", "gen": 80,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}}
            ]}}"#,
            p = p64.join(",")
        );
        let body = format!(r#"{{"pipelines": [{good}, {bad}]}}"#);
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps[0].get("stages").and_then(Json::as_arr).unwrap().len(), 1);
        assert!(ps[1]
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("max_seq_len"));
        srv.shutdown();
    }

    #[test]
    fn pipeline_streams_per_stage_events() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..128).map(|t| (t % 4000).to_string()).collect();
        let body = format!(
            r#"{{"stream": true, "stages": [
                {{"name": "draft", "gen": 8, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 4, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}}
            ]}}"#,
            p = prompt.join(",")
        );
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        assert!(r.contains("Transfer-Encoding: chunked"), "{r}");
        assert!(r.contains("text/event-stream"), "{r}");
        // Per-stage lifecycle in completion order: each stage announces
        // itself, streams every token, then retires — and the run ends
        // with `done`.
        let pairs: Vec<(&str, Json)> = sse_pairs(&r);
        let kinds: Vec<&str> = pairs.iter().map(|(k, _)| *k).collect();
        let expect: Vec<&str> = std::iter::once("stage_started")
            .chain(std::iter::repeat("token").take(8))
            .chain(["stage_finished", "stage_started"])
            .chain(std::iter::repeat("token").take(4))
            .chain(["stage_finished", "done"])
            .collect();
        assert_eq!(kinds, expect, "{r}");
        // stage_started / token events carry their stage's name.
        assert_eq!(pairs[0].1.get("stage").and_then(Json::as_str), Some("draft"));
        assert_eq!(pairs[1].1.get("stage").and_then(Json::as_str), Some("draft"));
        assert_eq!(pairs[10].1.get("stage").and_then(Json::as_str), Some("check"));
        assert_eq!(pairs[11].1.get("stage").and_then(Json::as_str), Some("check"));
        // Token indices count up from 0 within each stage.
        assert_eq!(pairs[1].1.get("index").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pairs[8].1.get("index").and_then(Json::as_f64), Some(7.0));
        assert_eq!(pairs[11].1.get("index").and_then(Json::as_f64), Some(0.0));
        // stage_finished keeps the per-stage result payload.
        assert_eq!(pairs[9].1.get("name").and_then(Json::as_str), Some("draft"));
        let check = &pairs[15].1;
        assert_eq!(check.get("name").and_then(Json::as_str), Some("check"));
        assert!(check.get("cache_hit_rate").and_then(Json::as_f64).unwrap() > 0.5);
        assert!(pairs[16].1.get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
        // A bad streaming spec fails as a plain error response (nothing
        // was streamed yet), and batches can't stream.
        let r = post(srv.addr(), "/pipeline", r#"{"stream": true, "stages": []}"#);
        assert!(r.contains("400"), "{r}");
        let r = post(srv.addr(), "/pipeline", r#"{"stream": true, "pipelines": []}"#);
        assert!(r.contains("400"), "{r}");
        srv.shutdown();
    }

    /// Parse an SSE response body into (event, data) pairs.
    fn sse_pairs(r: &str) -> Vec<(&str, Json)> {
        let events: Vec<&str> = r
            .lines()
            .filter(|l| l.starts_with("event: "))
            .map(|l| l.trim_start_matches("event: "))
            .collect();
        let datas: Vec<Json> = r
            .lines()
            .filter(|l| l.starts_with("data: "))
            .map(|l| Json::parse(l.trim_start_matches("data: ")).unwrap())
            .collect();
        assert_eq!(events.len(), datas.len(), "{r}");
        events.into_iter().zip(datas).collect()
    }

    #[test]
    fn pipeline_stream_tokens_match_non_streamed_run() {
        // Same spec against two fresh engines: the streamed token events,
        // concatenated per stage, must be byte-identical to the
        // non-streamed response's token arrays — streaming is an
        // observation channel, not a different execution.
        let stages = r#""stages": [
            {"name": "draft", "gen": 8, "prompt": [[1,2,3,4,5,6,7,8]]},
            {"name": "check", "adapter": "alora-0", "gen": 4, "invoke": true,
             "prompt": [{"prompt_of": "draft"}, {"output_of": "draft"}]}
        ]"#;
        let mut plain = start_sim_server();
        let r = post(plain.addr(), "/pipeline", &format!("{{{stages}}}"));
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let mut want: Vec<(String, Vec<u32>)> = j
            .get("stages")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|s| {
                let name = s.get("name").and_then(Json::as_str).unwrap().to_string();
                let toks = s
                    .get("tokens")
                    .and_then(Json::as_arr)
                    .unwrap()
                    .iter()
                    .map(|t| t.as_f64().unwrap() as u32)
                    .collect();
                (name, toks)
            })
            .collect();
        want.sort();
        plain.shutdown();

        let mut srv = start_sim_server();
        let r = post(srv.addr(), "/pipeline", &format!(r#"{{"stream": true, {stages}}}"#));
        assert!(r.contains("200 OK"), "{r}");
        let mut streamed: std::collections::BTreeMap<String, Vec<u32>> =
            std::collections::BTreeMap::new();
        for (kind, data) in sse_pairs(&r) {
            if kind != "token" {
                continue;
            }
            let stage = data.get("stage").and_then(Json::as_str).unwrap().to_string();
            let toks = streamed.entry(stage).or_default();
            // In-order delivery: each token's index is its position.
            assert_eq!(data.get("index").and_then(Json::as_f64), Some(toks.len() as f64));
            toks.push(data.get("token").and_then(Json::as_f64).unwrap() as u32);
        }
        let got: Vec<(String, Vec<u32>)> = streamed.into_iter().collect();
        assert_eq!(got, want);
        srv.shutdown();
    }

    #[test]
    fn generate_cache_salt_isolates_tenants_over_http() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        let gen = |salt: &str| {
            let body = format!(
                r#"{{"prompt": [{}], "max_new_tokens": 2, "cache_salt": {salt}}}"#,
                prompt.join(",")
            );
            let r = post(srv.addr(), "/generate", &body);
            assert!(r.contains("200 OK"), "{r}");
            body_json(&r).get("cache_hit_rate").and_then(Json::as_f64).unwrap()
        };
        assert_eq!(gen("\"tenant-a\""), 0.0, "cold");
        assert!(gen("\"tenant-a\"") > 0.5, "same tenant rehits its prefix");
        assert_eq!(gen("\"tenant-b\""), 0.0, "tenants never share hits");
        assert_eq!(gen("7"), 0.0, "numeric salt is its own tenant");
        srv.shutdown();
    }

    #[test]
    fn cluster_mode_serves_and_reports_fleet_stats() {
        let mut srv = start_cluster_server(2);
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        for _ in 0..2 {
            let body = format!(
                r#"{{"prompt": [{}], "max_new_tokens": 2}}"#,
                prompt.join(",")
            );
            assert!(post(srv.addr(), "/generate", &body).contains("200 OK"));
        }
        let r = http(srv.addr(), "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("prefix-affinity"));
        assert_eq!(j.get("replicas").and_then(Json::as_arr).unwrap().len(), 2);
        // Fleet dashboards get the per-replica config summary + adapter
        // residency without out-of-band config.
        let cfg = j.get("config").expect("config summary");
        assert_eq!(cfg.get("model").and_then(Json::as_str), Some("granite-8b"));
        assert!(cfg.get("total_blocks").and_then(Json::as_u64).unwrap() > 0);
        assert_eq!(cfg.get("adapter_paging").and_then(Json::as_bool), Some(false));
        let rep0 = &j.get("replicas").and_then(Json::as_arr).unwrap()[0];
        assert!(rep0.get("resident_adapters").and_then(Json::as_arr).is_some());
        assert!(rep0.get("adapter_loads").and_then(Json::as_u64).is_some());
        // Tiered-memory fields (DESIGN.md §20): a uniform no-host-tier
        // fleet reports zeros, but the keys are always present.
        assert_eq!(rep0.get("host_total_blocks").and_then(Json::as_u64), Some(0));
        assert_eq!(rep0.get("adapter_host_blocks").and_then(Json::as_u64), Some(0));
        assert_eq!(rep0.get("adapter_demotions").and_then(Json::as_u64), Some(0));
        assert_eq!(rep0.get("adapter_promotions").and_then(Json::as_u64), Some(0));
        assert_eq!(rep0.get("adapter_host_drops").and_then(Json::as_u64), Some(0));
        assert_eq!(rep0.get("adapter_prefetches").and_then(Json::as_u64), Some(0));
        let m = http(srv.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("alora_serve_router_requests_routed_total"), "{m}");
        assert!(m.contains("alora_serve_replica_clock_seconds{replica=\"1\"}"));
        srv.shutdown();
        // Single-engine servers now answer with a one-replica document
        // instead of 404 (API-consistency satellite).
        let mut single = start_sim_server();
        let body = format!(r#"{{"prompt": [{}], "max_new_tokens": 2}}"#, prompt.join(","));
        assert!(post(single.addr(), "/generate", &body).contains("200 OK"));
        let r = http(single.addr(), "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("single"));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].get("finished").and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("config").unwrap().get("model").and_then(Json::as_str), Some("granite-8b"));
        single.shutdown();
    }

    #[test]
    fn bad_requests_get_structured_envelopes() {
        let mut srv = start_sim_server();
        // Wrong-typed field -> invalid_request.
        let r = post(srv.addr(), "/generate", r#"{"prompt": "nope"}"#);
        assert!(r.contains("400"), "{r}");
        let j = body_json(&r);
        assert_eq!(
            j.get("error").unwrap().get("code").and_then(Json::as_str),
            Some("invalid_request")
        );
        // Malformed JSON -> invalid_json, on every POST endpoint.
        for path in ["/generate", "/pipeline", "/v1/sessions"] {
            let r = post(srv.addr(), path, "{not json");
            assert!(r.contains("400"), "{path}: {r}");
            let j = body_json(&r);
            assert_eq!(
                j.get("error").unwrap().get("code").and_then(Json::as_str),
                Some("invalid_json"),
                "{path}"
            );
        }
        // Empty body -> missing_body.
        let r = post(srv.addr(), "/generate", "");
        assert!(r.contains("400"), "{r}");
        assert!(r.contains("\"code\":\"missing_body\""), "{r}");
        // Unknown adapter -> 404 unknown_adapter.
        let r = post(srv.addr(), "/generate", r#"{"prompt": [1,2], "adapter": "ghost-9"}"#);
        assert!(r.contains("404"), "{r}");
        assert!(r.contains("\"code\":\"unknown_adapter\""), "{r}");
        // Unknown route -> 404 envelope.
        let r = http(srv.addr(), "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"), "{r}");
        assert!(r.contains("\"code\":\"not_found\""), "{r}");
        // Oversized body refused up front with 413.
        let r = http(
            srv.addr(),
            &format!(
                "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
                MAX_BODY_BYTES + 1
            ),
        );
        assert!(r.contains("413"), "{r}");
        assert!(r.contains("\"code\":\"payload_too_large\""), "{r}");
        srv.shutdown();
    }

    #[test]
    fn replica_admin_endpoints_fail_drain_restore() {
        let mut srv = start_cluster_server(2);
        let addr = srv.addr();
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        let gen_body = format!(r#"{{"prompt": [{}], "max_new_tokens": 2}}"#, prompt.join(","));
        assert!(post(addr, "/generate", &gen_body).contains("200 OK"));

        // Drain replica 1, check health surfaces in GET /cluster.
        let r = post(addr, "/cluster/replicas/1/drain", "");
        assert!(r.contains("200 OK"), "{r}");
        assert_eq!(body_json(&r).get("health").and_then(Json::as_str), Some("draining"));
        let j = body_json(&http(addr, "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n"));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps[0].get("health").and_then(Json::as_str), Some("up"));
        assert_eq!(reps[1].get("health").and_then(Json::as_str), Some("draining"));

        // Restore, then fail it; the failure response reports the repair.
        assert!(post(addr, "/cluster/replicas/1/restore", "").contains("200 OK"));
        let r = post(addr, "/cluster/replicas/1/fail", "");
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("health").and_then(Json::as_str), Some("down"));
        assert!(j.get("requeued").and_then(Json::as_u64).is_some());
        assert!(j.get("orphaned_leases").and_then(Json::as_u64).is_some());
        // Serving continues on the survivor; metrics expose the failover
        // counters.
        assert!(post(addr, "/generate", &gen_body).contains("200 OK"));
        let m = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("alora_serve_replica_failures_total 1"), "{m}");
        assert!(m.contains("alora_serve_requeued_requests_total"), "{m}");
        assert!(m.contains("alora_serve_resticks_total"), "{m}");

        // State conflicts and unknown replicas get the right envelopes.
        let r = post(addr, "/cluster/replicas/1/fail", "");
        assert!(r.contains("409"), "{r}");
        assert!(r.contains("\"code\":\"replica_state\""), "{r}");
        let r = post(addr, "/cluster/replicas/0/fail", "");
        assert!(r.contains("409"), "no survivor: {r}");
        let r = post(addr, "/cluster/replicas/9/drain", "");
        assert!(r.contains("404"), "{r}");
        assert!(r.contains("\"code\":\"replica_not_found\""), "{r}");
        let r = post(addr, "/cluster/replicas/1/explode", "");
        assert!(r.contains("404"), "unknown action routes nowhere: {r}");
        // Restore the failed replica; it serves again (cold).
        assert!(post(addr, "/cluster/replicas/1/restore", "").contains("200 OK"));
        assert!(post(addr, "/generate", &gen_body).contains("200 OK"));
        srv.shutdown();

        // Single-engine servers refuse replica admin with a clear 400.
        let mut single = start_sim_server();
        let r = post(single.addr(), "/cluster/replicas/0/fail", "");
        assert!(r.contains("400"), "{r}");
        assert!(r.contains("no fleet"), "{r}");
        single.shutdown();
    }

    #[test]
    fn replica_action_path_parser() {
        assert_eq!(parse_replica_action("/cluster/replicas/0/fail"), Some((0, "fail")));
        assert_eq!(parse_replica_action("/cluster/replicas/3/drain"), Some((3, "drain")));
        assert_eq!(
            parse_replica_action("/cluster/replicas/12/restore"),
            Some((12, "restore"))
        );
        assert_eq!(
            parse_replica_action("/cluster/replicas/2/silence"),
            Some((2, "silence"))
        );
        assert_eq!(parse_replica_action("/cluster/replicas/x/fail"), None);
        assert_eq!(parse_replica_action("/cluster/replicas/0/explode"), None);
        assert_eq!(parse_replica_action("/cluster/replicas/0/fail/extra"), None);
        assert_eq!(parse_replica_action("/cluster/replicas/0/silence/extra"), None);
        assert_eq!(parse_replica_action("/cluster/replicas/0"), None);
        assert_eq!(parse_replica_action("/cluster"), None);
    }

    #[test]
    fn cluster_health_endpoint_and_silence_action() {
        let mut srv = start_cluster_server(2);
        let addr = srv.addr();
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        let gen_body = format!(r#"{{"prompt": [{}], "max_new_tokens": 8}}"#, prompt.join(","));

        // The detector's view before any traffic: everyone up, nobody
        // silenced, thresholds from the default fleet config.
        let r = http(addr, "GET /cluster/health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("suspect_after_misses").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("down_after_misses").and_then(Json::as_f64), Some(6.0));
        assert_eq!(j.get("num_healthy").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("detected_failures").and_then(Json::as_f64), Some(0.0));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps.len(), 2);
        assert_eq!(reps[0].get("health_detail").and_then(Json::as_str), Some("up"));
        assert_eq!(reps[1].get("silenced"), Some(&Json::Bool(false)));

        // GET /cluster carries the same fine-grained state per replica.
        let j = body_json(&http(addr, "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n"));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps[0].get("health_detail").and_then(Json::as_str), Some("up"));
        assert_eq!(reps[1].get("health_detail").and_then(Json::as_str), Some("up"));

        // Silence replica 1 (a partition, not a crash) ...
        let r = post(addr, "/cluster/replicas/1/silence", "");
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("replica").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("silenced"), Some(&Json::Bool(true)));

        // ... then just serve: driver steps double as monitoring rounds,
        // so ordinary traffic walks the victim Up → Suspected → Down and
        // runs the failover pipeline with no admin call. The request
        // itself still completes (zero lost requests).
        assert!(post(addr, "/generate", &gen_body).contains("200 OK"));
        let j = body_json(&http(addr, "GET /cluster/health HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert_eq!(j.get("num_healthy").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("detected_failures").and_then(Json::as_f64), Some(1.0));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps[1].get("health").and_then(Json::as_str), Some("down"));
        let m = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("alora_serve_detected_failures_total 1"), "{m}");
        assert!(m.contains("alora_serve_suspected_transitions_total 1"), "{m}");
        assert!(m.contains("alora_serve_heartbeat_misses_total 6"), "{m}");

        // Conflicts and unknowns map to the usual envelopes.
        let r = post(addr, "/cluster/replicas/1/silence", "");
        assert!(r.contains("409"), "{r}");
        assert!(r.contains("\"code\":\"replica_state\""), "{r}");
        let r = post(addr, "/cluster/replicas/9/silence", "");
        assert!(r.contains("404"), "{r}");
        assert!(r.contains("\"code\":\"replica_not_found\""), "{r}");
        srv.shutdown();

        // Single engines: no detector, no heartbeat surface.
        let mut single = start_sim_server();
        let r = http(single.addr(), "GET /cluster/health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"), "{r}");
        let r = post(single.addr(), "/cluster/replicas/0/silence", "");
        assert!(r.contains("400"), "{r}");
        assert!(r.contains("no fleet"), "{r}");
        single.shutdown();
    }

    #[test]
    fn session_path_parser() {
        assert_eq!(parse_session_path("/v1/sessions/3"), Some((3, SessionRoute::Root)));
        assert_eq!(
            parse_session_path("/v1/sessions/3/turns"),
            Some((3, SessionRoute::Turns))
        );
        assert_eq!(
            parse_session_path("/v1/sessions/3/fork"),
            Some((3, SessionRoute::Fork))
        );
        assert_eq!(parse_session_path("/v1/sessions/x"), None);
        assert_eq!(parse_session_path("/v1/sessions/3/other"), None);
        assert_eq!(parse_session_path("/v1/sessions/3/turns/4"), None);
        assert_eq!(parse_session_path("/v1/sessions/3/fork/2"), None);
        assert_eq!(parse_session_path("/v2/sessions/3"), None);
    }

    /// `POST /v1/sessions/{id}/fork` end to end: children share the
    /// parent's history, a per-child adapter becomes that child's
    /// default turn target, and validation rejects garbage before any
    /// child exists.
    #[test]
    fn fork_endpoint_creates_prefix_sharing_children() {
        let mut srv = start_sim_server();
        let addr = srv.addr();
        let r = post(addr, "/v1/sessions", r#"{"cache_salt": 5}"#);
        assert!(r.contains("200 OK"), "{r}");
        let sid = body_json(&r).get("session").and_then(Json::as_u64).unwrap();
        let tokens: Vec<String> = (0..64).map(|t| (t % 4000).to_string()).collect();
        let r = post(
            addr,
            &format!("/v1/sessions/{sid}/turns"),
            &format!(r#"{{"tokens": [{}], "max_new_tokens": 2}}"#, tokens.join(",")),
        );
        assert!(r.contains("200 OK"), "{r}");
        let history = body_json(&r).get("prompt_len").and_then(Json::as_u64).unwrap() + 2;

        // Fork 3 ways: child 0 pinned to alora-0, children 1–2 plain.
        let r = post(
            addr,
            &format!("/v1/sessions/{sid}/fork"),
            r#"{"count": 3, "adapters": ["alora-0", null]}"#,
        );
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("parent").and_then(Json::as_u64), Some(sid));
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(3));
        let kids = j.get("children").and_then(Json::as_arr).unwrap();
        assert_eq!(kids.len(), 3);
        assert_eq!(
            kids[0].get("adapter").and_then(Json::as_str),
            Some("alora-0"),
            "{j}"
        );
        assert!(matches!(kids[1].get("adapter"), Some(Json::Null)));
        let child0 = kids[0].get("session").and_then(Json::as_u64).unwrap();
        let child1 = kids[1].get("session").and_then(Json::as_u64).unwrap();

        // Children carry the parent's full history, zero turns of their own.
        let r = http(addr, &format!("GET /v1/sessions/{child1} HTTP/1.1\r\nHost: x\r\n\r\n"));
        let doc = body_json(&r);
        assert_eq!(doc.get("history_len").and_then(Json::as_u64), Some(history));
        assert_eq!(doc.get("turns").and_then(Json::as_arr).map(Vec::len), Some(0));

        // A turn on child 0 with no adapter in the body runs the child's
        // preferred target — the fork-time adapter, not base.
        let r = post(
            addr,
            &format!("/v1/sessions/{child0}/turns"),
            r#"{"tokens": [9, 9, 9], "max_new_tokens": 2}"#,
        );
        assert!(r.contains("200 OK"), "{r}");
        assert_eq!(
            body_json(&r).get("adapter").and_then(Json::as_str),
            Some("alora-0"),
            "forked child must default to its preferred adapter"
        );

        // Validation: unknown parent 404s, silly counts and unknown
        // adapters reject without creating children.
        let before = srv.shared.sessions.len();
        let r = post(addr, "/v1/sessions/999/fork", r#"{"count": 1}"#);
        assert!(r.contains("404"), "{r}");
        let r = post(addr, &format!("/v1/sessions/{sid}/fork"), r#"{"count": 0}"#);
        assert!(r.contains("400"), "{r}");
        let r = post(
            addr,
            &format!("/v1/sessions/{sid}/fork"),
            r#"{"count": 1, "adapters": ["nope"]}"#,
        );
        assert!(r.contains("404"), "{r}");
        assert_eq!(srv.shared.sessions.len(), before, "failed forks leak sessions");
        srv.shutdown();
    }

    /// The lock-split smoke test (ISSUE 7 satellite): 8 handler threads
    /// hammer the session API concurrently; afterwards the engine's pool
    /// invariant must hold (free + adapter-resident + leased == total)
    /// and every request must have been counted exactly once.
    #[test]
    fn concurrent_handlers_keep_pool_invariant_and_exact_counts() {
        let mut srv = start_sim_server();
        let addr = srv.addr();
        const THREADS: u64 = 8;
        const TURNS: u64 = 3;
        let handles: Vec<_> = (0..THREADS)
            .map(|th| {
                std::thread::spawn(move || {
                    let r = post(addr, "/v1/sessions", &format!(r#"{{"cache_salt": {th}}}"#));
                    assert!(r.contains("200 OK"), "{r}");
                    let sid = body_json(&r).get("session").and_then(Json::as_u64).unwrap();
                    for turn in 0..TURNS {
                        let tokens: Vec<String> = (0..48)
                            .map(|t| ((th * 7919 + turn * 131 + t) % 4000).to_string())
                            .collect();
                        let body = format!(
                            r#"{{"tokens": [{}], "max_new_tokens": 2}}"#,
                            tokens.join(",")
                        );
                        let r = post(addr, &format!("/v1/sessions/{sid}/turns"), &body);
                        assert!(r.contains("200 OK"), "{r}");
                    }
                    sid
                })
            })
            .collect();
        let sids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let unique: std::collections::HashSet<u64> = sids.iter().copied().collect();
        assert_eq!(unique.len(), THREADS as usize, "session ids must be distinct");
        assert_eq!(srv.shared.sessions.len(), THREADS as usize);
        // Exactly one received + one finished per turn, across all
        // threads — nothing double-counted, nothing dropped.
        let (received, finished) = srv.shared.call(|engine, _| {
            let m = engine.metrics_mut();
            (m.requests_received, m.requests_finished)
        });
        assert_eq!(received, THREADS * TURNS);
        assert_eq!(finished, THREADS * TURNS);
        srv.shared.call(|engine, _| engine.check_invariants()).unwrap();
        // Closing every session releases its lease; the pool must still
        // balance afterwards.
        for sid in unique {
            let r = http(
                addr,
                &format!("DELETE /v1/sessions/{sid} HTTP/1.1\r\nHost: x\r\n\r\n"),
            );
            assert!(r.contains("200 OK"), "{r}");
        }
        assert_eq!(srv.shared.sessions.len(), 0);
        srv.shared.call(|engine, _| engine.check_invariants()).unwrap();
        srv.shutdown();
    }
}
