//! HTTP entrypoint (vLLM-style): the conversation-first v1 API plus the
//! legacy one-shot endpoints.
//!
//! Hand-rolled HTTP/1.1 over std TCP (no tokio in the offline build — see
//! DESIGN.md §7). The server drives any [`EngineDriver`] — one engine or a
//! replica [`crate::cluster::Cluster`] (every submission is routed; session
//! turns are sticky-routed to their conversation's replica). A dedicated
//! driver thread owns stepping; handler threads submit requests and block
//! on a condvar until their request completes — or, for streaming turns,
//! consume the engine's [`TurnEvent`] emission incrementally and forward
//! it as HTTP/1.1 chunked SSE. Request lifecycle timestamps still come
//! from the virtual clock, so `/metrics` exposes the same Table-2 series
//! the figure harness reads.
//!
//! API (full reference with curl examples: API.md; semantics: DESIGN.md
//! §14):
//!
//!   POST   /v1/sessions              {"cache_salt": 7 | "tenant" (opt)}
//!     -> {"session": 0, "cache_salt": "..."}
//!   POST   /v1/sessions/{id}/turns   {"tokens": [delta...],
//!                                     "adapter": "alora-0"|null,
//!                                     "max_new_tokens": 16,
//!                                     "append": true, "stream": false}
//!     -> turn summary JSON; with "stream": true -> chunked SSE events
//!        (`started`, `token`*, `finished`) whose token sequence is
//!        byte-identical to the non-streaming `tokens`
//!   GET    /v1/sessions              {"sessions": [ids], "count": n}
//!   GET    /v1/sessions/{id}         session document (history, turns)
//!   DELETE /v1/sessions/{id}         close + release the prefix lease
//!
//!   POST /generate   legacy one-shot (bit-identical response shape);
//!                    thin shim over the same submit/wait internals
//!   POST /pipeline   stage-graph spec (single or {"pipelines": [...]});
//!                    "stream": true on a single spec -> SSE `stage`
//!                    events as stages retire, then `done`
//!   GET  /metrics    Prometheus text exposition
//!   GET  /cluster    fleet stats JSON incl. per-replica health (single
//!                    engines report a one-replica document — never 404)
//!   POST /cluster/replicas/{i}/{fail|drain|restore}
//!                    replica administration (no body): fail evacuates +
//!                    requeues the replica's work onto survivors and
//!                    repairs affected sessions; drain excludes it from
//!                    new placements while it finishes; restore returns
//!                    it to rotation (cold after a failure)
//!   GET  /health     {"status": "ok"}
//!
//! Every error is a structured envelope with a meaningful status code:
//! `{"error": {"code": "...", "message": "..."}}` — `invalid_json`,
//! `missing_body`, `payload_too_large` (413), `unknown_adapter` (404),
//! `session_not_found` (404), `turn_in_flight` (409), `timeout` (504),
//! `invalid_request`, `not_found`.

pub mod v1;

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::adapter::AdapterRegistry;
use crate::coordinator::{spec, Coordinator};
use crate::engine::EngineDriver;
use crate::kvcache::hash::tenant_salt;
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams, TurnEvent};
use crate::session::SessionManager;
use crate::util::json::Json;

/// Bodies past this are refused with 413 before being read.
pub const MAX_BODY_BYTES: usize = 8 << 20;
/// Absolute per-request deadline, blocking and streaming paths alike
/// (virtual work is fast; this guards against stalls, not slow models).
pub(crate) const REQUEST_TIMEOUT: Duration = Duration::from_secs(60);

pub(crate) struct Shared<D: EngineDriver> {
    pub(crate) engine: Mutex<EngineState<D>>,
    pub(crate) cv: Condvar,
    stop: AtomicBool,
}

pub(crate) struct EngineState<D: EngineDriver> {
    pub(crate) engine: D,
    /// Conversation state behind the v1 endpoints.
    pub(crate) sessions: SessionManager,
    pub(crate) done: HashMap<RequestId, RequestOutput>,
    /// Requests abandoned by their handler (e.g. a timed-out request):
    /// the driver drops their outputs instead of parking them in `done`
    /// forever.
    pub(crate) orphaned: HashSet<RequestId>,
    /// Streaming turns: per-request event sinks the driver thread fills
    /// from `take_events` and the streaming handler drains. Requests with
    /// a sink get their finished output through it (as
    /// [`TurnEvent::Finished`]), not through `done`.
    pub(crate) streams: HashMap<RequestId, Vec<TurnEvent>>,
    /// Requests that will NEVER produce an output (failover requeue
    /// rejected them on every survivor). Waiters resolve against this
    /// immediately instead of burning the full 60 s deadline.
    pub(crate) failed: HashSet<RequestId>,
}

// ---------------------------------------------------------------------------
// Structured error envelope (satellite): {"error": {"code", "message"}}.

#[derive(Debug)]
pub struct ApiError {
    pub status: &'static str,
    pub code: &'static str,
    pub message: String,
}

impl ApiError {
    pub fn new(status: &'static str, code: &'static str, message: impl Into<String>) -> Self {
        ApiError { status, code, message: message.into() }
    }

    pub fn bad_request(code: &'static str, message: impl Into<String>) -> Self {
        Self::new("400 Bad Request", code, message)
    }

    pub fn not_found(code: &'static str, message: impl Into<String>) -> Self {
        Self::new("404 Not Found", code, message)
    }

    pub fn conflict(code: &'static str, message: impl Into<String>) -> Self {
        Self::new("409 Conflict", code, message)
    }

    pub fn timeout(message: impl Into<String>) -> Self {
        Self::new("504 Gateway Timeout", "timeout", message)
    }

    /// The envelope body.
    pub fn body(&self) -> String {
        Json::obj(vec![("error", self.event_json())]).to_string()
    }

    /// The inner object (also the payload of a streaming `error` event).
    pub fn event_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("message", Json::str(self.message.clone())),
        ])
    }
}

/// Map engine/session errors onto the envelope. The lower layers speak
/// `anyhow` with stable message prefixes; this is the single place that
/// translates them into wire codes, so handlers never hand-classify.
pub(crate) fn classify(e: anyhow::Error) -> ApiError {
    let message = e.to_string();
    if message.contains("unknown adapter") {
        ApiError::not_found("unknown_adapter", message)
    } else if message.contains("unknown session") {
        ApiError::not_found("session_not_found", message)
    } else if message.contains("in flight") {
        ApiError::conflict("turn_in_flight", message)
    } else if message.contains("timed out") {
        ApiError::timeout(message)
    } else if message.starts_with("no replica ") {
        ApiError::not_found("replica_not_found", message)
    } else if message.contains("already down")
        || message.contains("already up")
        || message.contains("only an up replica")
        || message.contains("last healthy")
        || message.contains("no healthy survivor")
    {
        // Replica admin against the wrong current state (fail a dead
        // replica, drain the last one, ...): a state conflict, not a
        // malformed request.
        ApiError::conflict("replica_state", message)
    } else {
        ApiError::bad_request("invalid_request", message)
    }
}

/// Resolve an optional adapter name against the registry (404 envelope on
/// unknown names — the satellite's "correct status codes" contract).
pub(crate) fn resolve_target(
    registry: &AdapterRegistry,
    name: Option<&str>,
) -> Result<ModelTarget, ApiError> {
    match name {
        None => Ok(ModelTarget::Base),
        Some(n) => registry
            .by_name(n)
            .map(|a| ModelTarget::Adapter(a.id))
            .ok_or_else(|| ApiError::not_found("unknown_adapter", format!("unknown adapter `{n}`"))),
    }
}

// ---------------------------------------------------------------------------
// Server lifecycle.

/// A running server; `shutdown()` or drop stops the driver thread.
pub struct Server<D: EngineDriver + Send + 'static> {
    shared: Arc<Shared<D>>,
    addr: std::net::SocketAddr,
    listener_handle: Option<std::thread::JoinHandle<()>>,
    driver_handle: Option<std::thread::JoinHandle<()>>,
}

impl<D: EngineDriver + Send + 'static> Server<D> {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port) and start
    /// the driver + listener threads. `engine` is any [`EngineDriver`]:
    /// pass an [`crate::engine::Engine`] for single-replica serving or a
    /// [`crate::cluster::Cluster`] for routed fleet serving.
    pub fn start(engine: D, addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            engine: Mutex::new(EngineState {
                engine,
                sessions: SessionManager::new(),
                done: HashMap::new(),
                orphaned: HashSet::new(),
                streams: HashMap::new(),
                failed: HashSet::new(),
            }),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });

        // Driver thread: steps the engine whenever there is work, then
        // routes the step's emissions — turn events into their streaming
        // sinks, finished outputs into `done` (streamed requests deliver
        // through their sink instead; orphans are dropped).
        let driver = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || loop {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                let mut st = shared.engine.lock().unwrap();
                if st.engine.has_work() {
                    st.engine.step();
                    let events = st.engine.take_events();
                    for ev in events {
                        if let Some(sink) = st.streams.get_mut(&ev.id()) {
                            sink.push(ev);
                        }
                        // No sink: the subscription was abandoned between
                        // emission and drain — drop the event.
                    }
                    let finished = st.engine.take_finished();
                    for out in finished {
                        if st.streams.contains_key(&out.id) {
                            continue; // delivered via the event sink
                        }
                        if !st.orphaned.remove(&out.id) {
                            st.done.insert(out.id, out);
                        }
                    }
                    shared.cv.notify_all();
                    drop(st);
                } else {
                    // Idle: wait for submissions.
                    let _ = shared
                        .cv
                        .wait_timeout(st, Duration::from_millis(10))
                        .unwrap();
                }
            })
        };

        // Listener thread: accept + handle connections (one thread each).
        let listener_handle = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || loop {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let shared = Arc::clone(&shared);
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, &shared);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            })
        };

        Ok(Server {
            shared,
            addr: local,
            listener_handle: Some(listener_handle),
            driver_handle: Some(driver),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        if let Some(h) = self.listener_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.driver_handle.take() {
            let _ = h.join();
        }
    }
}

impl<D: EngineDriver + Send + 'static> Drop for Server<D> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Connection handling.

/// What a routed request resolves to: a complete response, or a streaming
/// handler that owns the socket from here on.
enum Reply {
    Full { status: &'static str, ctype: &'static str, body: String },
    TurnStream { session: u64, turn: v1::TurnBody },
    PipelineStream { spec: Json },
}

fn full_ok(body: String) -> Reply {
    Reply::Full { status: "200 OK", ctype: "application/json", body }
}

fn full_err(e: ApiError) -> Reply {
    Reply::Full { status: e.status, ctype: "application/json", body: e.body() }
}

fn from_result(r: Result<Json, ApiError>) -> Reply {
    match r {
        Ok(j) => full_ok(j.to_string()),
        Err(e) => full_err(e),
    }
}

pub(crate) fn write_response(
    stream: &mut TcpStream,
    status: &str,
    ctype: &str,
    content: &str,
) -> anyhow::Result<()> {
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        content.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(content.as_bytes())?;
    Ok(())
}

// -- HTTP/1.1 chunked SSE plumbing (streaming turns & pipelines) ------------

pub(crate) fn start_stream(stream: &mut TcpStream) -> anyhow::Result<()> {
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nTransfer-Encoding: chunked\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    Ok(())
}

fn write_chunk(stream: &mut TcpStream, payload: &str) -> anyhow::Result<()> {
    stream.write_all(format!("{:x}\r\n", payload.len()).as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.write_all(b"\r\n")?;
    Ok(())
}

/// One SSE event as one chunk: `event: <name>\ndata: <json>\n\n`.
pub(crate) fn write_sse(stream: &mut TcpStream, event: &str, data: &Json) -> anyhow::Result<()> {
    write_chunk(stream, &format!("event: {event}\ndata: {data}\n\n"))
}

/// Terminal zero-length chunk.
pub(crate) fn end_stream(stream: &mut TcpStream) -> anyhow::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    Ok(())
}

fn handle_conn<D: EngineDriver>(mut stream: TcpStream, shared: &Shared<D>) -> anyhow::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    if content_len > MAX_BODY_BYTES {
        // Refuse before reading: an oversized body never enters memory.
        let e = ApiError::new(
            "413 Payload Too Large",
            "payload_too_large",
            format!("body of {content_len} bytes exceeds the {MAX_BODY_BYTES}-byte limit"),
        );
        return write_response(&mut stream, e.status, "application/json", &e.body());
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }

    match route(&method, &path, &body, shared) {
        Reply::Full { status, ctype, body } => write_response(&mut stream, status, ctype, &body),
        Reply::TurnStream { session, turn } => v1::stream_turn(&mut stream, shared, session, turn),
        Reply::PipelineStream { spec } => stream_pipeline(&mut stream, shared, &spec),
    }
}

fn route<D: EngineDriver>(method: &str, path: &str, body: &[u8], shared: &Shared<D>) -> Reply {
    match method {
        "GET" => match path {
            "/health" => Reply::Full {
                status: "200 OK",
                ctype: "application/json",
                body: r#"{"status":"ok"}"#.into(),
            },
            "/metrics" => {
                let st = shared.engine.lock().unwrap();
                Reply::Full {
                    status: "200 OK",
                    ctype: "text/plain; version=0.0.4",
                    body: st.engine.render_prometheus(),
                }
            }
            "/cluster" => {
                let st = shared.engine.lock().unwrap();
                match st.engine.cluster_stats() {
                    Some(cs) => full_ok(cs.to_json().to_string()),
                    // Unreachable for the in-tree drivers (a single engine
                    // reports a one-replica document), kept for third-party
                    // EngineDriver impls without stats.
                    None => full_err(ApiError::not_found(
                        "not_found",
                        "this driver exposes no fleet stats",
                    )),
                }
            }
            "/v1/sessions" => from_result(v1::list_sessions(shared)),
            p => match parse_session_path(p) {
                Some((sid, false)) => from_result(v1::get_session(shared, sid)),
                _ => full_err(ApiError::not_found("not_found", format!("no route for GET {p}"))),
            },
        },
        "POST" => {
            // Replica administration takes no body — route it before the
            // body requirement.
            if let Some((i, action)) = parse_replica_action(path) {
                return from_result(replica_action(shared, i, action));
            }
            if path.starts_with("/cluster/replicas/") {
                return full_err(ApiError::not_found(
                    "not_found",
                    format!("no route for POST {path} (actions: fail, drain, restore)"),
                ));
            }
            if body.is_empty() {
                return full_err(ApiError::bad_request(
                    "missing_body",
                    "POST endpoints require a JSON body",
                ));
            }
            let j = match std::str::from_utf8(body).map_err(|e| e.to_string()).and_then(
                |text| Json::parse(text).map_err(|e| e.to_string()),
            ) {
                Ok(j) => j,
                Err(e) => return full_err(ApiError::bad_request("invalid_json", e)),
            };
            match path {
                "/generate" => from_result(generate(&j, shared)),
                "/pipeline" => {
                    if j.get("stream").and_then(Json::as_bool).unwrap_or(false) {
                        if j.get("pipelines").is_some() {
                            return full_err(ApiError::bad_request(
                                "invalid_request",
                                "streaming supports a single spec, not a `pipelines` batch",
                            ));
                        }
                        return Reply::PipelineStream { spec: j };
                    }
                    from_result(run_pipeline(&j, shared).map_err(classify))
                }
                "/v1/sessions" => from_result(v1::create_session(&j, shared)),
                p => match parse_session_path(p) {
                    Some((sid, true)) => match v1::parse_turn(&j) {
                        Err(e) => full_err(e),
                        Ok(turn) if turn.stream => Reply::TurnStream { session: sid, turn },
                        Ok(turn) => from_result(v1::run_turn(shared, sid, turn)),
                    },
                    _ => full_err(ApiError::not_found(
                        "not_found",
                        format!("no route for POST {p}"),
                    )),
                },
            }
        }
        "DELETE" => match parse_session_path(path) {
            Some((sid, false)) => from_result(v1::delete_session(shared, sid)),
            _ => full_err(ApiError::not_found(
                "not_found",
                format!("no route for DELETE {path}"),
            )),
        },
        m => full_err(ApiError::not_found("not_found", format!("no route for {m} {path}"))),
    }
}

/// Parse `/cluster/replicas/{i}/{fail|drain|restore}` admin paths.
fn parse_replica_action(path: &str) -> Option<(usize, &str)> {
    let rest = path.strip_prefix("/cluster/replicas/")?;
    let mut parts = rest.split('/');
    let i: usize = parts.next()?.parse().ok()?;
    let action = parts.next()?;
    if parts.next().is_some() || !matches!(action, "fail" | "drain" | "restore") {
        return None;
    }
    Some((i, action))
}

/// Replica administration (`POST /cluster/replicas/{i}/{fail|drain|restore}`).
/// `fail` additionally repairs the session layer — orphaned leases are
/// forgotten, stranded conversations lose their stickiness peer (they
/// re-stick on their next turn), and turns whose requeue was rejected are
/// aborted — and wakes the driver so requeued work starts immediately.
fn replica_action<D: EngineDriver>(
    shared: &Shared<D>,
    i: usize,
    action: &str,
) -> Result<Json, ApiError> {
    let mut g = shared.engine.lock().unwrap();
    let st = &mut *g;
    match action {
        "fail" => {
            let report = st.engine.fail_replica(i).map_err(classify)?;
            let (leases_dropped, resticks_pending, turns_aborted) =
                st.sessions.repair_after_failover(&mut st.engine, &report);
            // Requests no survivor accepted will never finish: tombstone
            // them so their blocked waiters fail NOW, not at the 60 s
            // deadline.
            st.failed.extend(report.rejected.iter().copied());
            shared.cv.notify_all();
            Ok(Json::obj(vec![
                ("replica", Json::num(i as f64)),
                ("health", Json::str("down")),
                ("requeued", Json::num(report.requeued as f64)),
                ("rejected", Json::num(report.rejected.len() as f64)),
                (
                    "orphaned_leases",
                    Json::num(report.orphaned_leases.len() as f64),
                ),
                ("sessions_leases_dropped", Json::num(leases_dropped as f64)),
                ("sessions_unstuck", Json::num(resticks_pending as f64)),
                ("turns_aborted", Json::num(turns_aborted as f64)),
            ]))
        }
        "drain" => {
            st.engine.drain_replica(i).map_err(classify)?;
            Ok(Json::obj(vec![
                ("replica", Json::num(i as f64)),
                ("health", Json::str("draining")),
            ]))
        }
        "restore" => {
            st.engine.restore_replica(i).map_err(classify)?;
            Ok(Json::obj(vec![
                ("replica", Json::num(i as f64)),
                ("health", Json::str("up")),
            ]))
        }
        _ => unreachable!("parse_replica_action filtered"),
    }
}

/// Parse `/v1/sessions/{id}` and `/v1/sessions/{id}/turns` paths into
/// (id, is_turns). None for anything else.
fn parse_session_path(path: &str) -> Option<(u64, bool)> {
    let rest = path.strip_prefix("/v1/sessions/")?;
    let mut parts = rest.split('/');
    let id: u64 = parts.next()?.parse().ok()?;
    match parts.next() {
        None => Some((id, false)),
        Some("turns") if parts.next().is_none() => Some((id, true)),
        _ => None,
    }
}

/// Parse the optional multi-tenant `cache_salt` field: a raw u64, or a
/// tenant-name string hashed to a stable nonzero salt.
pub(crate) fn parse_cache_salt(req: &Json) -> anyhow::Result<u64> {
    match req.get("cache_salt") {
        None | Some(Json::Null) => Ok(0),
        Some(v) => {
            if let Some(n) = v.as_u64() {
                Ok(n)
            } else if let Some(s) = v.as_str() {
                Ok(tenant_salt(s))
            } else {
                anyhow::bail!("`cache_salt` must be an integer or a tenant string")
            }
        }
    }
}

/// Block until the driver thread finishes `id`, with an absolute deadline
/// (the condvar is woken on every driver step, so a per-wait timeout
/// would reset forever under concurrent traffic). Shared by `/generate`
/// and non-streaming turns — the legacy endpoint is a shim over the same
/// wait the v1 path uses.
pub(crate) fn wait_done<D: EngineDriver>(
    shared: &Shared<D>,
    id: RequestId,
) -> Result<RequestOutput, ApiError> {
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    let mut st = shared.engine.lock().unwrap();
    loop {
        if let Some(out) = st.done.remove(&id) {
            return Ok(out);
        }
        if st.failed.remove(&id) {
            // Lost to a replica failure and rejected by every survivor:
            // no output will ever come.
            return Err(ApiError::new(
                "502 Bad Gateway",
                "request_failed",
                format!("request {id:?} was lost to a replica failure and could not be requeued"),
            ));
        }
        let now = Instant::now();
        if now >= deadline {
            // Abandoning the request: let the driver drop its output
            // instead of parking it in `done` forever.
            st.orphaned.insert(id);
            return Err(ApiError::timeout(format!("request {id:?} timed out")));
        }
        let (guard, _) = shared.cv.wait_timeout(st, deadline - now).unwrap();
        st = guard;
    }
}

// ---------------------------------------------------------------------------
// Legacy endpoints (thin shims over the shared internals; success
// responses are bit-identical to the pre-v1 server).

/// The legacy `/generate` wire shape — exact field set and ordering
/// (object keys serialize sorted), pinned by tests.
fn generate_response(out: &RequestOutput) -> Json {
    Json::obj(vec![
        ("id", Json::num(out.id.0 as f64)),
        (
            "tokens",
            Json::Arr(out.output_tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("e2e_s", Json::num(out.timeline.e2e())),
        ("ttft_s", Json::num(out.timeline.ttft())),
        ("itl_s", Json::num(out.itl())),
        ("cache_hit_rate", Json::num(out.cache_hit_rate())),
        ("preemptions", Json::num(out.preemptions as f64)),
    ])
}

fn generate<D: EngineDriver>(j: &Json, shared: &Shared<D>) -> Result<Json, ApiError> {
    let prompt = j.get("prompt").and_then(Json::u32_vec).ok_or_else(|| {
        ApiError::bad_request("invalid_request", "`prompt` must be an array of token ids")
    })?;
    let max_new = j.get("max_new_tokens").and_then(Json::as_u64).unwrap_or(16) as u32;
    let adapter_name = j.get("adapter").and_then(Json::as_str).map(str::to_string);
    let cache_salt = parse_cache_salt(j).map_err(classify)?;

    let id = {
        let mut st = shared.engine.lock().unwrap();
        let target = resolve_target(st.engine.registry(), adapter_name.as_deref())?;
        let id = st
            .engine
            .submit_salted(
                target,
                prompt,
                SamplingParams { max_new_tokens: max_new, ..Default::default() },
                false,
                cache_salt,
            )
            .map_err(classify)?;
        shared.cv.notify_all();
        id
    };
    wait_done(shared, id).map(|out| generate_response(&out))
}

/// Orphan every in-flight stage of an abandoned coordinator run: drop
/// outputs already in `done`, mark the rest so the driver discards them
/// on arrival. The single cleanup used by every /pipeline abort path.
fn orphan_in_flight<D: EngineDriver>(st: &mut EngineState<D>, co: &Coordinator) {
    for id in co.in_flight_ids() {
        if st.done.remove(&id).is_none() {
            st.orphaned.insert(id);
        }
    }
}

/// Abandon one batch-`/pipeline` conversation after a submission failure:
/// hand its in-flight outputs to the orphan list (the driver discards
/// them) and record the per-entry error in input order. Shared by the
/// root-submission and chain-time failure paths so their bookkeeping
/// cannot diverge.
fn abandon_batch_entry<D: EngineDriver>(
    co: &mut Coordinator,
    st: &mut EngineState<D>,
    convs: &mut [Result<usize, String>],
    ci: usize,
    err: String,
) {
    for id in co.abandon_conversation(ci) {
        if st.done.remove(&id).is_none() {
            st.orphaned.insert(id);
        }
    }
    if let Some(idx) = convs.iter().position(|c| c.as_ref().ok() == Some(&ci)) {
        convs[idx] = Err(err);
    }
}

/// Drive one or many stage-graph conversations to completion over the
/// shared engine. The driver thread does the stepping; this handler
/// consumes its conversations' completions from `done` and lets the
/// coordinator chain children the moment their parents retire.
///
/// Batch form (`{"pipelines": [spec, ...]}`): every parseable graph runs;
/// graphs that fail validation — or whose submission the engine rejects
/// at runtime (e.g. a stage exceeding max_seq_len) — get a per-entry
/// `error` in the response instead of failing the whole request (a 400
/// is reserved for structural problems — non-array `pipelines`, empty
/// batch, unparseable body).
fn run_pipeline<D: EngineDriver>(spec_json: &Json, shared: &Shared<D>) -> anyhow::Result<Json> {
    let mut st = shared.engine.lock().unwrap();
    let (specs, batched): (Vec<&Json>, bool) = match spec_json.get("pipelines") {
        Some(pj) => {
            let arr = pj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("`pipelines` must be an array of specs"))?;
            anyhow::ensure!(!arr.is_empty(), "`pipelines` is empty");
            (arr.iter().collect(), true)
        }
        None => (vec![spec_json], false),
    };
    let mut co = Coordinator::new();
    // Per input spec: the conversation index it became, or its error.
    let mut convs: Vec<Result<usize, String>> = Vec::new();
    for &sj in &specs {
        let parsed = spec::graph_from_json(sj, st.engine.registry())
            .and_then(|g| co.add_conversation(g));
        convs.push(parsed.map_err(|e| e.to_string()));
    }
    if !batched {
        // Single-spec form keeps its contract: invalid spec = 400.
        if let Err(e) = &convs[0] {
            anyhow::bail!("{e}");
        }
    }
    let n_stages: usize = convs
        .iter()
        .flatten()
        .map(|&ci| co.graph(ci).len())
        .sum();
    let t0 = st.engine.clock();
    // Every failure past this point must fall through to the cleanup arm
    // below (partially-submitted roots are already in flight), so no `?`.
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    let mut outcome = Ok(());
    for idx in 0..convs.len() {
        let Ok(&ci) = convs[idx].as_ref() else { continue };
        if let Err(e) = co.submit_ready(&mut st.engine, ci) {
            if batched {
                // Isolate the failing graph: abandon it (its partially
                // submitted roots keep running; their outputs get
                // discarded) and report it per-entry — a runtime reject
                // in one graph must not fail the rest of the batch.
                abandon_batch_entry(&mut co, &mut st, &mut convs, ci, e.to_string());
            } else {
                outcome = Err(e);
                break;
            }
        }
    }
    shared.cv.notify_all();

    while outcome.is_ok() && !co.is_done() {
        let ready: Vec<RequestId> =
            st.done.keys().copied().filter(|id| co.owns(*id)).collect();
        if ready.is_empty() {
            // A stage lost to a replica failure (requeue rejected) will
            // never retire: fail the conversation now, not at deadline.
            let lost: Vec<RequestId> =
                st.failed.iter().copied().filter(|id| co.owns(*id)).collect();
            if !lost.is_empty() {
                for id in &lost {
                    st.failed.remove(id);
                }
                outcome = Err(anyhow::anyhow!(
                    "pipeline stage request {lost:?} was lost to a replica failure"
                ));
                break;
            }
            // Absolute deadline: the condvar is woken on every driver
            // step, so a per-wait timeout would reset forever under
            // concurrent traffic.
            let now = Instant::now();
            if now >= deadline {
                outcome = Err(anyhow::anyhow!(
                    "pipeline timed out with {} of {n_stages} stages unfinished",
                    co.in_flight()
                ));
                break;
            }
            let (guard, _) = shared.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            continue;
        }
        for id in ready {
            // An abandonment earlier in this drain may have already
            // discarded a sibling stage's output.
            let Some(out) = st.done.remove(&id) else { continue };
            let ci = co.conversation_of(id);
            if let Err(e) = co.on_finished(&mut st.engine, out) {
                // Child-stage submission can fail at chaining time (e.g. a
                // composed prompt outgrowing max_seq_len). In batch mode
                // that conversation alone is abandoned and reported
                // per-entry, same as a root-submission failure.
                match ci {
                    Some(ci) if batched => {
                        abandon_batch_entry(&mut co, &mut st, &mut convs, ci, e.to_string());
                    }
                    _ => {
                        outcome = Err(e);
                        break;
                    }
                }
            }
        }
        // Children were just submitted — wake the driver.
        shared.cv.notify_all();
    }

    match outcome {
        Ok(()) => {
            let makespan = st.engine.clock() - t0;
            let result = co.into_result(makespan);
            if batched {
                Ok(spec::batch_result_to_json(&result, &convs))
            } else {
                Ok(spec::result_to_json(&result))
            }
        }
        Err(e) => {
            // Abandoning the conversation: drop anything of ours already
            // in `done` and mark the still-running stages orphaned so the
            // driver discards their outputs instead of leaking them.
            orphan_in_flight(&mut st, &co);
            Err(e)
        }
    }
}

/// What one wake-up of a streaming wait produced.
enum StreamStep {
    /// Newly retired stage JSONs, whether the run completed, makespan.
    Emit(Vec<Json>, bool, f64),
    Fail(ApiError),
}

/// Streaming `/pipeline` (single spec): per-stage SSE emission through
/// the coordinator's completion stream — a `stage` event the moment each
/// stage retires (ROADMAP "streaming per-stage results over HTTP"), then
/// `done` with the makespan.
fn stream_pipeline<D: EngineDriver>(
    stream: &mut TcpStream,
    shared: &Shared<D>,
    spec_json: &Json,
) -> anyhow::Result<()> {
    let mut co = Coordinator::new();
    let t0 = {
        let mut g = shared.engine.lock().unwrap();
        let st = &mut *g;
        let submitted = spec::graph_from_json(spec_json, st.engine.registry())
            .and_then(|graph| co.add_conversation(graph))
            .and_then(|ci| co.submit_ready(&mut st.engine, ci));
        match submitted {
            Ok(_) => {
                shared.cv.notify_all();
                st.engine.clock()
            }
            Err(e) => {
                // Nothing streamed yet: plain error response.
                let err = classify(e);
                return write_response(stream, err.status, "application/json", &err.body());
            }
        }
    };
    let result = stream_pipeline_events(stream, shared, &mut co, t0);
    if result.is_err() {
        // A socket write failed mid-stream (client went away): orphan the
        // coordinator's in-flight stages so the driver discards their
        // outputs instead of leaking them into the shared `done` map.
        let mut g = shared.engine.lock().unwrap();
        orphan_in_flight(&mut g, &co);
    }
    result
}

/// The emission phase of a streaming pipeline. Any `Err` here is a dead
/// client socket — `stream_pipeline` orphans the leftovers; engine-side
/// failures are reported in-band as `error` events (with their own
/// orphan handling under the lock).
fn stream_pipeline_events<D: EngineDriver>(
    stream: &mut TcpStream,
    shared: &Shared<D>,
    co: &mut Coordinator,
    t0: f64,
) -> anyhow::Result<()> {
    start_stream(stream)?;
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    let mut emitted = 0usize;
    loop {
        let step = {
            let mut g = shared.engine.lock().unwrap();
            loop {
                let st = &mut *g;
                let ready: Vec<RequestId> =
                    st.done.keys().copied().filter(|id| co.owns(*id)).collect();
                let mut failed: Option<anyhow::Error> = None;
                let mut chained = false;
                for id in ready {
                    let Some(out) = st.done.remove(&id) else { continue };
                    if let Err(e) = co.on_finished(&mut st.engine, out) {
                        failed = Some(e);
                        break;
                    }
                    chained = true;
                }
                if chained {
                    shared.cv.notify_all();
                }
                if let Some(e) = failed {
                    orphan_in_flight(st, co);
                    break StreamStep::Fail(classify(e));
                }
                let new: Vec<Json> = co
                    .finished_since(emitted)
                    .iter()
                    .map(spec::stage_output_to_json)
                    .collect();
                if !new.is_empty() || co.is_done() {
                    emitted = co.finished_stages().len();
                    break StreamStep::Emit(new, co.is_done(), st.engine.clock() - t0);
                }
                // A stage lost to a replica failure never retires: fail
                // the stream now instead of at the deadline.
                let lost: Vec<RequestId> =
                    st.failed.iter().copied().filter(|id| co.owns(*id)).collect();
                if !lost.is_empty() {
                    for id in &lost {
                        st.failed.remove(id);
                    }
                    orphan_in_flight(st, co);
                    break StreamStep::Fail(ApiError::new(
                        "502 Bad Gateway",
                        "request_failed",
                        format!("pipeline stage request {lost:?} was lost to a replica failure"),
                    ));
                }
                let now = Instant::now();
                if now >= deadline {
                    orphan_in_flight(st, co);
                    break StreamStep::Fail(ApiError::timeout(format!(
                        "pipeline timed out with {} stages in flight",
                        co.in_flight()
                    )));
                }
                let (guard, _) = shared.cv.wait_timeout(g, deadline - now).unwrap();
                g = guard;
            }
        };
        match step {
            StreamStep::Fail(e) => {
                write_sse(stream, "error", &e.event_json())?;
                return end_stream(stream);
            }
            StreamStep::Emit(new, done, makespan) => {
                for j in &new {
                    write_sse(stream, "stage", j)?;
                }
                if done {
                    write_sse(
                        stream,
                        "done",
                        &Json::obj(vec![("makespan_s", Json::num(makespan))]),
                    )?;
                    return end_stream(stream);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, RoutePolicy};
    use crate::config::presets;
    use crate::engine::Engine;
    use crate::pipeline::workload;
    use crate::simulator::SimExecutor;

    fn sim_engine() -> Engine<SimExecutor> {
        let cfg = presets::granite_8b();
        let reg = workload::build_registry(2, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    }

    fn start_sim_server() -> Server<Engine<SimExecutor>> {
        Server::start(sim_engine(), "127.0.0.1:0").unwrap()
    }

    fn start_cluster_server(n: usize) -> Server<Cluster<SimExecutor>> {
        let cluster =
            Cluster::from_factory(n, RoutePolicy::PrefixAffinity, |_| sim_engine()).unwrap();
        Server::start(cluster, "127.0.0.1:0").unwrap()
    }

    fn http(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
        http(
            addr,
            &format!(
                "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            ),
        )
    }

    /// Last line of an HTTP response = the JSON body (Content-Length
    /// framing, single-line JSON).
    fn body_json(resp: &str) -> Json {
        Json::parse(resp.lines().last().unwrap()).unwrap()
    }

    #[test]
    fn health_and_metrics_endpoints() {
        let mut srv = start_sim_server();
        let r = http(srv.addr(), "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK") && r.contains("\"ok\""));
        let r = http(srv.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("alora_serve_requests_received_total"));
        srv.shutdown();
    }

    #[test]
    fn generate_roundtrip_base_and_adapter() {
        let mut srv = start_sim_server();
        let r = post(srv.addr(), "/generate", r#"{"prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 4}"#);
        assert!(r.contains("200 OK"), "{r}");
        assert!(r.contains("\"tokens\""));

        let r = post(
            srv.addr(),
            "/generate",
            r#"{"prompt": [1,2,3,4], "adapter": "alora-1", "max_new_tokens": 2}"#,
        );
        assert!(r.contains("200 OK"), "{r}");
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_runs_stage_graph() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..256).map(|t| (t % 4000).to_string()).collect();
        let body = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 32, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 8, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}],
                  "priority": true}},
                {{"name": "final", "gen": 8,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}},
                             {{"output_of": "check"}}]}}
            ]}}"#,
            p = prompt.join(",")
        );
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let stages = j.get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(stages.len(), 3);
        // downstream stages reuse upstream KV over HTTP too
        for s in stages {
            let name = s.get("name").and_then(Json::as_str).unwrap();
            let hit = s.get("cache_hit_rate").and_then(Json::as_f64).unwrap();
            if name != "draft" {
                assert!(hit > 0.5, "{name}: hit {hit}");
            }
        }
        assert!(j.get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_rejects_bad_spec() {
        let mut srv = start_sim_server();
        for body in [
            r#"{"stages": []}"#,
            r#"{"stages": [{"name": "a", "prompt": [{"output_of": "ghost"}]}]}"#,
        ] {
            let r = post(srv.addr(), "/pipeline", body);
            assert!(r.contains("400"), "{r}");
            assert!(r.contains("\"code\":\"invalid_request\""), "{r}");
        }
        srv.shutdown();
    }

    #[test]
    fn pipeline_endpoint_batches_graphs_with_per_graph_errors() {
        let mut srv = start_sim_server();
        let p: Vec<String> = (0..64).map(|t| (t % 4000).to_string()).collect();
        let good = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 8, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 4, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}}
            ]}}"#,
            p = p.join(",")
        );
        let bad = r#"{"stages": [{"name": "x", "prompt": [{"output_of": "ghost"}]}]}"#;
        let body = format!(r#"{{"pipelines": [{good}, {bad}, {good}]}}"#);
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps.len(), 3);
        for idx in [0usize, 2] {
            let stages = ps[idx].get("stages").and_then(Json::as_arr).unwrap();
            assert_eq!(stages.len(), 2, "pipeline {idx}");
            assert!(ps[idx].get("error").is_none());
        }
        assert!(ps[1].get("error").and_then(Json::as_str).unwrap().contains("ghost"));
        // A graph that passes validation but is rejected by the engine at
        // submission (gen beyond max_seq_len) is isolated the same way.
        let runtime_bad =
            r#"{"stages": [{"name": "x", "gen": 200000, "prompt": [[1,2,3]]}]}"#;
        let body = format!(r#"{{"pipelines": [{good}, {runtime_bad}]}}"#);
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps[0].get("stages").and_then(Json::as_arr).unwrap().len(), 2);
        assert!(ps[1]
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("max_seq_len"));
        // structural problems still 400
        for body in [r#"{"pipelines": []}"#, r#"{"pipelines": 5}"#] {
            assert!(post(srv.addr(), "/pipeline", body).contains("400"));
        }
        srv.shutdown();
    }

    #[test]
    fn pipeline_batch_isolates_child_stage_submit_failure() {
        // tiny preset: max_seq_len 160 — a child whose composed prompt
        // outgrows it is rejected only at CHAINING time, after its root
        // already ran. The batch must still return the good graph's
        // results with a per-entry error for the bad one.
        let cfg = presets::tiny();
        let reg = crate::adapter::AdapterRegistry::tiny_default(2, 512, 4);
        let exec = SimExecutor::new(&cfg);
        let mut srv =
            Server::start(Engine::with_registry(cfg, reg, exec), "127.0.0.1:0").unwrap();
        let good = r#"{"stages": [{"name": "a", "gen": 8, "prompt": [[1,2,3,4,5,6,7,8]]}]}"#;
        let p64: Vec<String> = (0..64).map(|t| (t % 400).to_string()).collect();
        let bad = format!(
            r#"{{"stages": [
                {{"name": "draft", "gen": 32, "prompt": [[{p}]]}},
                {{"name": "kid", "gen": 80,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}}
            ]}}"#,
            p = p64.join(",")
        );
        let body = format!(r#"{{"pipelines": [{good}, {bad}]}}"#);
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        let ps = j.get("pipelines").and_then(Json::as_arr).unwrap();
        assert_eq!(ps[0].get("stages").and_then(Json::as_arr).unwrap().len(), 1);
        assert!(ps[1]
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("max_seq_len"));
        srv.shutdown();
    }

    #[test]
    fn pipeline_streams_per_stage_events() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..128).map(|t| (t % 4000).to_string()).collect();
        let body = format!(
            r#"{{"stream": true, "stages": [
                {{"name": "draft", "gen": 8, "prompt": [[{p}]]}},
                {{"name": "check", "adapter": "alora-0", "gen": 4, "invoke": true,
                  "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}}
            ]}}"#,
            p = prompt.join(",")
        );
        let r = post(srv.addr(), "/pipeline", &body);
        assert!(r.contains("200 OK"), "{r}");
        assert!(r.contains("Transfer-Encoding: chunked"), "{r}");
        assert!(r.contains("text/event-stream"), "{r}");
        // Two stage events in completion order, then done.
        let events: Vec<&str> = r
            .lines()
            .filter(|l| l.starts_with("event: "))
            .map(|l| l.trim_start_matches("event: "))
            .collect();
        assert_eq!(events, vec!["stage", "stage", "done"], "{r}");
        let datas: Vec<Json> = r
            .lines()
            .filter(|l| l.starts_with("data: "))
            .map(|l| Json::parse(l.trim_start_matches("data: ")).unwrap())
            .collect();
        assert_eq!(datas[0].get("name").and_then(Json::as_str), Some("draft"));
        assert_eq!(datas[1].get("name").and_then(Json::as_str), Some("check"));
        assert!(datas[1].get("cache_hit_rate").and_then(Json::as_f64).unwrap() > 0.5);
        assert!(datas[2].get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
        // A bad streaming spec fails as a plain error response (nothing
        // was streamed yet), and batches can't stream.
        let r = post(srv.addr(), "/pipeline", r#"{"stream": true, "stages": []}"#);
        assert!(r.contains("400"), "{r}");
        let r = post(srv.addr(), "/pipeline", r#"{"stream": true, "pipelines": []}"#);
        assert!(r.contains("400"), "{r}");
        srv.shutdown();
    }

    #[test]
    fn generate_cache_salt_isolates_tenants_over_http() {
        let mut srv = start_sim_server();
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        let gen = |salt: &str| {
            let body = format!(
                r#"{{"prompt": [{}], "max_new_tokens": 2, "cache_salt": {salt}}}"#,
                prompt.join(",")
            );
            let r = post(srv.addr(), "/generate", &body);
            assert!(r.contains("200 OK"), "{r}");
            body_json(&r).get("cache_hit_rate").and_then(Json::as_f64).unwrap()
        };
        assert_eq!(gen("\"tenant-a\""), 0.0, "cold");
        assert!(gen("\"tenant-a\"") > 0.5, "same tenant rehits its prefix");
        assert_eq!(gen("\"tenant-b\""), 0.0, "tenants never share hits");
        assert_eq!(gen("7"), 0.0, "numeric salt is its own tenant");
        srv.shutdown();
    }

    #[test]
    fn cluster_mode_serves_and_reports_fleet_stats() {
        let mut srv = start_cluster_server(2);
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        for _ in 0..2 {
            let body = format!(
                r#"{{"prompt": [{}], "max_new_tokens": 2}}"#,
                prompt.join(",")
            );
            assert!(post(srv.addr(), "/generate", &body).contains("200 OK"));
        }
        let r = http(srv.addr(), "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("prefix-affinity"));
        assert_eq!(j.get("replicas").and_then(Json::as_arr).unwrap().len(), 2);
        // Fleet dashboards get the per-replica config summary + adapter
        // residency without out-of-band config.
        let cfg = j.get("config").expect("config summary");
        assert_eq!(cfg.get("model").and_then(Json::as_str), Some("granite-8b"));
        assert!(cfg.get("total_blocks").and_then(Json::as_u64).unwrap() > 0);
        assert_eq!(cfg.get("adapter_paging").and_then(Json::as_bool), Some(false));
        let rep0 = &j.get("replicas").and_then(Json::as_arr).unwrap()[0];
        assert!(rep0.get("resident_adapters").and_then(Json::as_arr).is_some());
        assert!(rep0.get("adapter_loads").and_then(Json::as_u64).is_some());
        let m = http(srv.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("alora_serve_router_requests_routed_total"), "{m}");
        assert!(m.contains("alora_serve_replica_clock_seconds{replica=\"1\"}"));
        srv.shutdown();
        // Single-engine servers now answer with a one-replica document
        // instead of 404 (API-consistency satellite).
        let mut single = start_sim_server();
        let body = format!(r#"{{"prompt": [{}], "max_new_tokens": 2}}"#, prompt.join(","));
        assert!(post(single.addr(), "/generate", &body).contains("200 OK"));
        let r = http(single.addr(), "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("single"));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].get("finished").and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("config").unwrap().get("model").and_then(Json::as_str), Some("granite-8b"));
        single.shutdown();
    }

    #[test]
    fn bad_requests_get_structured_envelopes() {
        let mut srv = start_sim_server();
        // Wrong-typed field -> invalid_request.
        let r = post(srv.addr(), "/generate", r#"{"prompt": "nope"}"#);
        assert!(r.contains("400"), "{r}");
        let j = body_json(&r);
        assert_eq!(
            j.get("error").unwrap().get("code").and_then(Json::as_str),
            Some("invalid_request")
        );
        // Malformed JSON -> invalid_json, on every POST endpoint.
        for path in ["/generate", "/pipeline", "/v1/sessions"] {
            let r = post(srv.addr(), path, "{not json");
            assert!(r.contains("400"), "{path}: {r}");
            let j = body_json(&r);
            assert_eq!(
                j.get("error").unwrap().get("code").and_then(Json::as_str),
                Some("invalid_json"),
                "{path}"
            );
        }
        // Empty body -> missing_body.
        let r = post(srv.addr(), "/generate", "");
        assert!(r.contains("400"), "{r}");
        assert!(r.contains("\"code\":\"missing_body\""), "{r}");
        // Unknown adapter -> 404 unknown_adapter.
        let r = post(srv.addr(), "/generate", r#"{"prompt": [1,2], "adapter": "ghost-9"}"#);
        assert!(r.contains("404"), "{r}");
        assert!(r.contains("\"code\":\"unknown_adapter\""), "{r}");
        // Unknown route -> 404 envelope.
        let r = http(srv.addr(), "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(r.contains("404"), "{r}");
        assert!(r.contains("\"code\":\"not_found\""), "{r}");
        // Oversized body refused up front with 413.
        let r = http(
            srv.addr(),
            &format!(
                "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
                MAX_BODY_BYTES + 1
            ),
        );
        assert!(r.contains("413"), "{r}");
        assert!(r.contains("\"code\":\"payload_too_large\""), "{r}");
        srv.shutdown();
    }

    #[test]
    fn replica_admin_endpoints_fail_drain_restore() {
        let mut srv = start_cluster_server(2);
        let addr = srv.addr();
        let prompt: Vec<String> = (0..64).map(|t| t.to_string()).collect();
        let gen_body = format!(r#"{{"prompt": [{}], "max_new_tokens": 2}}"#, prompt.join(","));
        assert!(post(addr, "/generate", &gen_body).contains("200 OK"));

        // Drain replica 1, check health surfaces in GET /cluster.
        let r = post(addr, "/cluster/replicas/1/drain", "");
        assert!(r.contains("200 OK"), "{r}");
        assert_eq!(body_json(&r).get("health").and_then(Json::as_str), Some("draining"));
        let j = body_json(&http(addr, "GET /cluster HTTP/1.1\r\nHost: x\r\n\r\n"));
        let reps = j.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(reps[0].get("health").and_then(Json::as_str), Some("up"));
        assert_eq!(reps[1].get("health").and_then(Json::as_str), Some("draining"));

        // Restore, then fail it; the failure response reports the repair.
        assert!(post(addr, "/cluster/replicas/1/restore", "").contains("200 OK"));
        let r = post(addr, "/cluster/replicas/1/fail", "");
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        assert_eq!(j.get("health").and_then(Json::as_str), Some("down"));
        assert!(j.get("requeued").and_then(Json::as_u64).is_some());
        assert!(j.get("orphaned_leases").and_then(Json::as_u64).is_some());
        // Serving continues on the survivor; metrics expose the failover
        // counters.
        assert!(post(addr, "/generate", &gen_body).contains("200 OK"));
        let m = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(m.contains("alora_serve_replica_failures_total 1"), "{m}");
        assert!(m.contains("alora_serve_requeued_requests_total"), "{m}");
        assert!(m.contains("alora_serve_resticks_total"), "{m}");

        // State conflicts and unknown replicas get the right envelopes.
        let r = post(addr, "/cluster/replicas/1/fail", "");
        assert!(r.contains("409"), "{r}");
        assert!(r.contains("\"code\":\"replica_state\""), "{r}");
        let r = post(addr, "/cluster/replicas/0/fail", "");
        assert!(r.contains("409"), "no survivor: {r}");
        let r = post(addr, "/cluster/replicas/9/drain", "");
        assert!(r.contains("404"), "{r}");
        assert!(r.contains("\"code\":\"replica_not_found\""), "{r}");
        let r = post(addr, "/cluster/replicas/1/explode", "");
        assert!(r.contains("404"), "unknown action routes nowhere: {r}");
        // Restore the failed replica; it serves again (cold).
        assert!(post(addr, "/cluster/replicas/1/restore", "").contains("200 OK"));
        assert!(post(addr, "/generate", &gen_body).contains("200 OK"));
        srv.shutdown();

        // Single-engine servers refuse replica admin with a clear 400.
        let mut single = start_sim_server();
        let r = post(single.addr(), "/cluster/replicas/0/fail", "");
        assert!(r.contains("400"), "{r}");
        assert!(r.contains("no fleet"), "{r}");
        single.shutdown();
    }

    #[test]
    fn replica_action_path_parser() {
        assert_eq!(parse_replica_action("/cluster/replicas/0/fail"), Some((0, "fail")));
        assert_eq!(parse_replica_action("/cluster/replicas/3/drain"), Some((3, "drain")));
        assert_eq!(
            parse_replica_action("/cluster/replicas/12/restore"),
            Some((12, "restore"))
        );
        assert_eq!(parse_replica_action("/cluster/replicas/x/fail"), None);
        assert_eq!(parse_replica_action("/cluster/replicas/0/explode"), None);
        assert_eq!(parse_replica_action("/cluster/replicas/0/fail/extra"), None);
        assert_eq!(parse_replica_action("/cluster/replicas/0"), None);
        assert_eq!(parse_replica_action("/cluster"), None);
    }

    #[test]
    fn session_path_parser() {
        assert_eq!(parse_session_path("/v1/sessions/3"), Some((3, false)));
        assert_eq!(parse_session_path("/v1/sessions/3/turns"), Some((3, true)));
        assert_eq!(parse_session_path("/v1/sessions/x"), None);
        assert_eq!(parse_session_path("/v1/sessions/3/other"), None);
        assert_eq!(parse_session_path("/v1/sessions/3/turns/4"), None);
        assert_eq!(parse_session_path("/v2/sessions/3"), None);
    }
}
