//! The v1 conversation endpoints: session CRUD and delta turns, streaming
//! and not (DESIGN.md §14; endpoint reference with curl examples: API.md).
//!
//! Handlers here are thin over [`crate::session::SessionManager`]: they
//! parse, resolve adapter names, and wait — all conversation semantics
//! (delta composition, continuation priority, sticky placement, prefix
//! leases, per-turn metrics) live in the session layer so the engine-level
//! tests exercise exactly what HTTP serves. Under the lock-split server
//! (DESIGN.md §17) engine work runs as driver commands; pure session-table
//! reads and turn aborts go straight at the sharded [`SessionManager`]
//! without a driver round-trip.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use crate::adapter::AdapterRegistry;
use crate::engine::EngineDriver;
use crate::request::session::{Session, SessionId, TurnRecord};
use crate::request::{ModelTarget, RequestId, RequestOutput, TurnEvent};
use crate::util::json::Json;

use super::{
    classify, end_stream, parse_cache_salt, resolve_target, start_stream, wait_done,
    write_response, write_sse, ApiError, Shared, SinkWait, StreamSink, WaitSlot,
    REQUEST_TIMEOUT,
};

/// A parsed `POST /v1/sessions/{id}/turns` body.
#[derive(Debug, Clone)]
pub(crate) struct TurnBody {
    pub tokens: Vec<u32>,
    pub adapter: Option<String>,
    pub max_new_tokens: u32,
    pub append: bool,
    pub stream: bool,
}

pub(crate) fn parse_turn(j: &Json) -> Result<TurnBody, ApiError> {
    let tokens = match j.get("tokens") {
        None | Some(Json::Null) => Vec::new(),
        Some(v) => v.u32_vec().ok_or_else(|| {
            ApiError::bad_request("invalid_request", "`tokens` must be an array of token ids")
        })?,
    };
    let adapter = match j.get("adapter") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| {
                    ApiError::bad_request(
                        "invalid_request",
                        "`adapter` must be a registry name or null",
                    )
                })?
                .to_string(),
        ),
    };
    let max_new_tokens =
        j.get("max_new_tokens").and_then(Json::as_u64).unwrap_or(16) as u32;
    let append = j.get("append").and_then(Json::as_bool).unwrap_or(true);
    let stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    Ok(TurnBody { tokens, adapter, max_new_tokens, append, stream })
}

/// Render a finished turn — the non-streaming response body and the
/// payload of the streaming `finished` event (identical by construction).
fn turn_json(registry: &AdapterRegistry, sid: SessionId, rec: &TurnRecord) -> Json {
    let adapter = match rec.target {
        ModelTarget::Base => Json::Null,
        ModelTarget::Adapter(aid) => registry
            .get(aid)
            .map(|a| Json::str(a.name.clone()))
            .unwrap_or(Json::Null),
    };
    Json::obj(vec![
        ("session", Json::num(sid.0 as f64)),
        ("turn", Json::num(rec.turn.0 as f64)),
        ("id", Json::num(rec.request.0 as f64)),
        ("adapter", adapter),
        (
            "tokens",
            Json::Arr(rec.output_tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("append", Json::Bool(rec.append)),
        ("delta_len", Json::num(rec.delta_len as f64)),
        ("prompt_len", Json::num(rec.prompt_len as f64)),
        ("e2e_s", Json::num(rec.e2e_s)),
        ("ttft_s", Json::num(rec.ttft_s)),
        ("itl_s", Json::num(rec.itl_s)),
        ("queue_s", Json::num(rec.queue_s)),
        ("cached_tokens", Json::num(rec.cached_tokens as f64)),
        ("cache_hit_rate", Json::num(rec.cache_hit_rate)),
        ("preemptions", Json::num(rec.preemptions as f64)),
    ])
}

pub(crate) fn create_session<D: EngineDriver>(
    j: &Json,
    shared: &Shared<D>,
) -> Result<Json, ApiError> {
    let cache_salt = parse_cache_salt(j).map_err(classify)?;
    // A command only for the metrics bump + the engine clock the manager
    // stamps: session creation itself is sharded-table work.
    let sid = shared.call(move |engine, sh| {
        let sid = sh.sessions.create(cache_salt);
        engine.metrics_mut().sessions_created += 1;
        sid
    });
    Ok(Json::obj(vec![
        ("session", Json::num(sid.0 as f64)),
        // Salts are u64 (tenant hashes exceed f64's exact range): string.
        ("cache_salt", Json::str(cache_salt.to_string())),
    ]))
}

pub(crate) fn list_sessions<D: EngineDriver>(shared: &Shared<D>) -> Result<Json, ApiError> {
    // Pure table read: straight at the sharded manager, no driver.
    let ids = shared.sessions.ids();
    Ok(Json::obj(vec![
        ("count", Json::num(ids.len() as f64)),
        (
            "sessions",
            Json::Arr(ids.iter().map(|s| Json::num(s.0 as f64)).collect()),
        ),
    ]))
}

/// The session document: a consistent clone snapshot out of the sharded
/// table (no driver round-trip for the read; one command only to reach
/// the registry for adapter names).
fn session_doc(registry: &AdapterRegistry, s: &Session) -> Json {
    Json::obj(vec![
        ("session", Json::num(s.id.0 as f64)),
        ("cache_salt", Json::str(s.cache_salt.to_string())),
        ("history_len", Json::num(s.history_len() as f64)),
        (
            "tokens",
            Json::Arr(s.tokens().iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("leased_blocks", Json::num(s.leased_blocks as f64)),
        ("in_flight", Json::Bool(s.in_flight().is_some())),
        (
            "turns",
            Json::Arr(s.turns().iter().map(|r| turn_json(registry, s.id, r)).collect()),
        ),
    ])
}

pub(crate) fn get_session<D: EngineDriver>(
    shared: &Shared<D>,
    sid: u64,
) -> Result<Json, ApiError> {
    let s = shared.sessions.get(SessionId(sid)).ok_or_else(|| {
        ApiError::not_found("session_not_found", format!("unknown session {sid}"))
    })?;
    Ok(shared.call(move |engine, _| session_doc(engine.registry(), &s)))
}

pub(crate) fn delete_session<D: EngineDriver>(
    shared: &Shared<D>,
    sid: u64,
) -> Result<Json, ApiError> {
    // A command: deletion releases the prefix lease, which is engine work.
    shared.call(move |engine, sh| {
        let s = match sh.sessions.delete(&mut *engine, SessionId(sid)) {
            Ok(s) => s,
            Err(e) => return Err(classify(e)),
        };
        engine.metrics_mut().sessions_closed += 1;
        Ok(Json::obj(vec![
            ("deleted", Json::num(sid as f64)),
            ("turns", Json::num(s.num_turns() as f64)),
            ("history_len", Json::num(s.history_len() as f64)),
        ]))
    })
}

/// Cap on `POST /v1/sessions/{id}/fork` fan-out — one request may not
/// pin an unbounded multiple of the parent's prefix.
const MAX_FORK_CHILDREN: usize = 64;

/// `POST /v1/sessions/{id}/fork`: K children sharing the parent's
/// history and cached prefix (semantics: [`crate::session::SessionManager::fork`];
/// DESIGN.md §18). Body: `{"count": K, "adapters": [name|null, ...]}` —
/// both optional; a null (or missing) adapter entry inherits the
/// parent's preferred target.
pub(crate) fn fork_session<D: EngineDriver>(
    j: &Json,
    shared: &Shared<D>,
    sid: u64,
) -> Result<Json, ApiError> {
    let count = match j.get("count") {
        None | Some(Json::Null) => 1,
        Some(v) => match v.as_u64() {
            Some(n) if (1..=MAX_FORK_CHILDREN as u64).contains(&n) => n as usize,
            _ => {
                return Err(ApiError::bad_request(
                    "invalid_request",
                    format!("`count` must be an integer in 1..={MAX_FORK_CHILDREN}"),
                ))
            }
        },
    };
    let adapters: Vec<Option<String>> = match j.get("adapters") {
        None | Some(Json::Null) => Vec::new(),
        Some(Json::Arr(xs)) if xs.len() <= count => {
            let mut names = Vec::with_capacity(xs.len());
            for v in xs {
                names.push(match v {
                    Json::Null => None,
                    v => Some(
                        v.as_str()
                            .ok_or_else(|| {
                                ApiError::bad_request(
                                    "invalid_request",
                                    "`adapters` entries must be registry names or null",
                                )
                            })?
                            .to_string(),
                    ),
                });
            }
            names
        }
        Some(_) => {
            return Err(ApiError::bad_request(
                "invalid_request",
                "`adapters` must be an array of at most `count` names/nulls",
            ))
        }
    };
    let parent = SessionId(sid);
    shared.call(move |engine, sh| {
        // Resolve names up front so an unknown adapter 404s before any
        // child exists (fork is all-or-nothing on validation).
        let mut targets: Vec<Option<ModelTarget>> = Vec::with_capacity(adapters.len());
        for a in &adapters {
            targets.push(match a {
                None => None,
                Some(n) => Some(resolve_target(engine.registry(), Some(n))?),
            });
        }
        let children =
            sh.sessions.fork(&mut *engine, parent, count, &targets).map_err(classify)?;
        engine.metrics_mut().sessions_created += children.len() as u64;
        let kids = children
            .iter()
            .map(|&c| {
                let adapter = match sh.sessions.preferred_target(c) {
                    Some(ModelTarget::Adapter(aid)) => engine
                        .registry()
                        .get(aid)
                        .map(|a| Json::str(a.name.clone()))
                        .unwrap_or(Json::Null),
                    _ => Json::Null,
                };
                Json::obj(vec![
                    ("session", Json::num(c.0 as f64)),
                    ("adapter", adapter),
                ])
            })
            .collect();
        Ok(Json::obj(vec![
            ("parent", Json::num(parent.0 as f64)),
            ("count", Json::num(children.len() as f64)),
            ("children", Json::Arr(kids)),
        ]))
    })
}

/// Where a turn's completion gets delivered.
enum TurnEntry {
    Wait(Arc<WaitSlot>),
    Stream(Arc<StreamSink>),
}

/// Validate + submit a turn as ONE driver command, registering the
/// delivery entry in the same command — no step can interleave between
/// submission and registration, so the output cannot slip past it.
fn submit_turn<D: EngineDriver>(
    shared: &Shared<D>,
    sid: SessionId,
    t: &TurnBody,
    entry: TurnEntry,
) -> Result<RequestId, ApiError> {
    let tokens = t.tokens.clone();
    let adapter = t.adapter.clone();
    let (max_new, append) = (t.max_new_tokens, t.append);
    shared.call(move |engine, sh| {
        // Unknown sessions surface from begin_turn, which classify() maps
        // to the 404 envelope — one translation point, no duplicate
        // pre-check. A body that names no adapter falls back to the
        // target the session was forked to serve (plain sessions: base).
        let target = match adapter.as_deref() {
            None => sh.sessions.preferred_target(sid).unwrap_or(ModelTarget::Base),
            Some(n) => match resolve_target(engine.registry(), Some(n)) {
                Ok(t) => t,
                Err(e) => return Err(e),
            },
        };
        let (_turn, rid) =
            match sh.sessions.begin_turn(&mut *engine, sid, target, tokens, max_new, append) {
                Ok(v) => v,
                Err(e) => return Err(classify(e)),
            };
        match entry {
            TurnEntry::Wait(slot) => sh.waiters.register_waiter(rid, slot),
            TurnEntry::Stream(sink) => {
                engine.watch(rid);
                sh.waiters.register_stream(rid, sink);
            }
        }
        Ok(rid)
    })
}

/// Non-streaming turn: submit the delta, wait for the driver thread,
/// apply the completion to the session, and return the turn summary.
pub(crate) fn run_turn<D: EngineDriver>(
    shared: &Shared<D>,
    sid: u64,
    t: TurnBody,
) -> Result<Json, ApiError> {
    let sid = SessionId(sid);
    let slot = WaitSlot::new();
    let rid = submit_turn(shared, sid, &t, TurnEntry::Wait(Arc::clone(&slot)))?;
    match wait_done(shared, rid, &slot) {
        Ok(out) => shared.call(move |engine, sh| {
            match sh.sessions.complete_turn(&mut *engine, sid, &out) {
                Ok(rec) => Ok(turn_json(engine.registry(), sid, &rec)),
                Err(e) => {
                    // A completion the session cannot apply must still
                    // clear OUR in-flight turn — every error exit routes
                    // through an abort or the session 409s forever (the
                    // stuck-turn bug). Guarded on the id: failover repair
                    // may have aborted this turn already and a NEWER live
                    // turn must not be destroyed.
                    sh.sessions.abort_turn_if(sid, rid);
                    Err(classify(e))
                }
            }
        }),
        Err(e) => {
            // The request was orphaned by wait_done; detach the session's
            // pending turn (if it is still ours) so the conversation
            // stays usable. Pure table write — no driver needed.
            shared.sessions.abort_turn_if(sid, rid);
            Err(e)
        }
    }
}

/// One wake-up's worth of a streaming turn wait.
enum TurnWait {
    Events(Vec<TurnEvent>),
    Fail(ApiError),
}

/// Streaming turn: chunked SSE — `started` (TTFT clock opens), one
/// `token` per generated token, then `finished` with the same summary the
/// non-streaming path returns (token sequences byte-identical by
/// construction: both come from the engine's single emission path).
pub(crate) fn stream_turn<D: EngineDriver>(
    stream: &mut TcpStream,
    shared: &Shared<D>,
    sid: u64,
    t: TurnBody,
) -> anyhow::Result<()> {
    let sid = SessionId(sid);
    let sink = StreamSink::new();
    let rid = match submit_turn(shared, sid, &t, TurnEntry::Stream(Arc::clone(&sink))) {
        Ok(rid) => rid,
        // Nothing streamed yet: plain error response.
        Err(e) => return write_response(stream, e.status, "application/json", &e.body()),
    };
    // The finished output the streaming phase has seen but not yet
    // applied to the session — carried across a write failure so cleanup
    // can still commit a turn that genuinely completed server-side.
    let mut unapplied: Option<RequestOutput> = None;
    let result = stream_turn_events(stream, shared, &sink, sid, rid, &mut unapplied);
    if result.is_err() {
        // A socket write failed mid-stream (client went away). The
        // session must not stay wedged and nothing may leak: drop the
        // sink registration and the event subscription; if the turn
        // actually finished (output in hand, or still sitting undelivered
        // in the sink), apply it — only the client missed the final
        // event. Otherwise detach the turn and deregister the request so
        // the driver discards its output on arrival.
        if unapplied.is_none() {
            unapplied = sink.find_finished();
        }
        let finished = unapplied.take();
        shared.call(move |engine, sh| {
            sh.waiters.remove(rid);
            engine.unwatch(rid);
            let turn_pending =
                sh.sessions.get(sid).map(|s| s.in_flight() == Some(rid)).unwrap_or(false);
            if turn_pending {
                match &finished {
                    Some(out) => {
                        // Completed server-side: keep the history truthful.
                        let _ = sh.sessions.complete_turn(&mut *engine, sid, out);
                    }
                    None => {
                        // Still running: the driver must discard its output.
                        sh.sessions.abort_turn_if(sid, rid);
                    }
                }
            }
        });
    }
    result
}

/// The streaming phase of a turn, from response headers to the terminal
/// chunk. Any `Err` here is a dead client socket — `stream_turn` cleans
/// up (using `unapplied`, the finished-but-not-yet-applied output, to
/// tell a completed turn from a still-running one); engine-side failures
/// are reported in-band as `error` events.
fn stream_turn_events<D: EngineDriver>(
    stream: &mut TcpStream,
    shared: &Shared<D>,
    sink: &StreamSink,
    sid: SessionId,
    rid: RequestId,
    unapplied: &mut Option<RequestOutput>,
) -> anyhow::Result<()> {
    start_stream(stream)?;
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    let mut finished: Option<RequestOutput> = None;
    'stream: while finished.is_none() {
        let step = match sink.wait(deadline) {
            SinkWait::Events(events) => TurnWait::Events(events),
            SinkWait::Lost => {
                // Failover rejected this request on every survivor: no
                // more events will ever arrive. reject() already dropped
                // the registration and the failover repair aborted the
                // session's turn; only the event subscription remains.
                shared.call(move |engine, _| engine.unwatch(rid));
                TurnWait::Fail(ApiError::new(
                    "502 Bad Gateway",
                    "request_failed",
                    format!(
                        "turn request {rid:?} was lost to a replica failure and could not be requeued"
                    ),
                ))
            }
            SinkWait::TimedOut => {
                // Abandon: deregister (the driver discards the output on
                // arrival), unsubscribe, detach the session's turn.
                shared.call(move |engine, sh| {
                    sh.waiters.remove(rid);
                    engine.unwatch(rid);
                    sh.sessions.abort_turn_if(sid, rid);
                });
                TurnWait::Fail(ApiError::timeout(format!("turn request {rid:?} timed out")))
            }
        };
        match step {
            TurnWait::Fail(e) => {
                write_sse(stream, "error", &e.event_json())?;
                return end_stream(stream);
            }
            TurnWait::Events(events) => {
                for ev in events {
                    match ev {
                        TurnEvent::Started { clock, arrival, .. } => {
                            write_sse(
                                stream,
                                "started",
                                &Json::obj(vec![
                                    ("session", Json::num(sid.0 as f64)),
                                    ("id", Json::num(rid.0 as f64)),
                                    ("t_s", Json::num(clock)),
                                    ("arrival_s", Json::num(arrival)),
                                    ("queue_s", Json::num(clock - arrival)),
                                ]),
                            )?;
                        }
                        TurnEvent::Token { index, token, clock, .. } => {
                            write_sse(
                                stream,
                                "token",
                                &Json::obj(vec![
                                    ("index", Json::num(index as f64)),
                                    ("token", Json::num(token as f64)),
                                    ("t_s", Json::num(clock)),
                                ]),
                            )?;
                        }
                        TurnEvent::Finished { output, .. } => {
                            *unapplied = Some(output.clone());
                            finished = Some(output);
                            continue 'stream; // falls out: finished is Some
                        }
                    }
                }
            }
        }
    }
    let out = finished.expect("loop exits only with an output");
    let reply = shared.call(move |engine, sh| {
        sh.waiters.remove(rid);
        match sh.sessions.complete_turn(&mut *engine, sid, &out) {
            Ok(rec) => Ok(turn_json(engine.registry(), sid, &rec)),
            Err(e) => {
                // Unapplicable completion: clear OUR in-flight turn so the
                // session keeps accepting turns (stuck-409 bugfix; id
                // guard protects a newer turn), and stop the cleanup path
                // from retrying the same apply.
                sh.sessions.abort_turn_if(sid, rid);
                Err(classify(e))
            }
        }
    });
    *unapplied = None; // applied (or aborted): cleanup must not re-apply
    match reply {
        Ok(j) => write_sse(stream, "finished", &j)?,
        Err(e) => write_sse(stream, "error", &e.event_json())?,
    }
    end_stream(stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn turn_body_parsing_defaults_and_rejections() {
        let j = Json::parse(r#"{"tokens": [1,2,3]}"#).unwrap();
        let t = parse_turn(&j).unwrap();
        assert_eq!(t.tokens, vec![1, 2, 3]);
        assert_eq!(t.max_new_tokens, 16);
        assert!(t.append && !t.stream);
        assert!(t.adapter.is_none());

        let j = Json::parse(
            r#"{"tokens": [], "adapter": "alora-0", "max_new_tokens": 4,
                "append": false, "stream": true}"#,
        )
        .unwrap();
        let t = parse_turn(&j).unwrap();
        assert_eq!(t.adapter.as_deref(), Some("alora-0"));
        assert_eq!(t.max_new_tokens, 4);
        assert!(!t.append && t.stream);

        // Null adapter is base; typed garbage is rejected.
        let j = Json::parse(r#"{"tokens": [1], "adapter": null}"#).unwrap();
        assert!(parse_turn(&j).unwrap().adapter.is_none());
        let j = Json::parse(r#"{"tokens": [1], "adapter": 3}"#).unwrap();
        assert!(parse_turn(&j).is_err());
        let j = Json::parse(r#"{"tokens": "nope"}"#).unwrap();
        assert!(parse_turn(&j).is_err());
    }
}
