//! The v1 conversation endpoints: session CRUD and delta turns, streaming
//! and not (DESIGN.md §14; endpoint reference with curl examples: API.md).
//!
//! Handlers here are thin over [`crate::session::SessionManager`]: they
//! parse, resolve adapter names, and wait — all conversation semantics
//! (delta composition, continuation priority, sticky placement, prefix
//! leases, per-turn metrics) live in the session layer so the engine-level
//! tests exercise exactly what HTTP serves.

use std::net::TcpStream;
use std::time::Instant;

use crate::adapter::AdapterRegistry;
use crate::engine::EngineDriver;
use crate::request::session::{SessionId, TurnRecord};
use crate::request::{ModelTarget, RequestId, RequestOutput, TurnEvent};
use crate::util::json::Json;

use super::{
    classify, end_stream, parse_cache_salt, resolve_target, start_stream, wait_done,
    write_response, write_sse, ApiError, Shared, REQUEST_TIMEOUT,
};

/// A parsed `POST /v1/sessions/{id}/turns` body.
#[derive(Debug, Clone)]
pub(crate) struct TurnBody {
    pub tokens: Vec<u32>,
    pub adapter: Option<String>,
    pub max_new_tokens: u32,
    pub append: bool,
    pub stream: bool,
}

pub(crate) fn parse_turn(j: &Json) -> Result<TurnBody, ApiError> {
    let tokens = match j.get("tokens") {
        None | Some(Json::Null) => Vec::new(),
        Some(v) => v.u32_vec().ok_or_else(|| {
            ApiError::bad_request("invalid_request", "`tokens` must be an array of token ids")
        })?,
    };
    let adapter = match j.get("adapter") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| {
                    ApiError::bad_request(
                        "invalid_request",
                        "`adapter` must be a registry name or null",
                    )
                })?
                .to_string(),
        ),
    };
    let max_new_tokens =
        j.get("max_new_tokens").and_then(Json::as_u64).unwrap_or(16) as u32;
    let append = j.get("append").and_then(Json::as_bool).unwrap_or(true);
    let stream = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    Ok(TurnBody { tokens, adapter, max_new_tokens, append, stream })
}

/// Render a finished turn — the non-streaming response body and the
/// payload of the streaming `finished` event (identical by construction).
fn turn_json(registry: &AdapterRegistry, sid: SessionId, rec: &TurnRecord) -> Json {
    let adapter = match rec.target {
        ModelTarget::Base => Json::Null,
        ModelTarget::Adapter(aid) => registry
            .get(aid)
            .map(|a| Json::str(a.name.clone()))
            .unwrap_or(Json::Null),
    };
    Json::obj(vec![
        ("session", Json::num(sid.0 as f64)),
        ("turn", Json::num(rec.turn.0 as f64)),
        ("id", Json::num(rec.request.0 as f64)),
        ("adapter", adapter),
        (
            "tokens",
            Json::Arr(rec.output_tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("append", Json::Bool(rec.append)),
        ("delta_len", Json::num(rec.delta_len as f64)),
        ("prompt_len", Json::num(rec.prompt_len as f64)),
        ("e2e_s", Json::num(rec.e2e_s)),
        ("ttft_s", Json::num(rec.ttft_s)),
        ("itl_s", Json::num(rec.itl_s)),
        ("queue_s", Json::num(rec.queue_s)),
        ("cached_tokens", Json::num(rec.cached_tokens as f64)),
        ("cache_hit_rate", Json::num(rec.cache_hit_rate)),
        ("preemptions", Json::num(rec.preemptions as f64)),
    ])
}

pub(crate) fn create_session<D: EngineDriver>(
    j: &Json,
    shared: &Shared<D>,
) -> Result<Json, ApiError> {
    let cache_salt = parse_cache_salt(j).map_err(classify)?;
    let mut st = shared.engine.lock().unwrap();
    let sid = st.sessions.create(cache_salt);
    st.engine.metrics_mut().sessions_created += 1;
    Ok(Json::obj(vec![
        ("session", Json::num(sid.0 as f64)),
        // Salts are u64 (tenant hashes exceed f64's exact range): string.
        ("cache_salt", Json::str(cache_salt.to_string())),
    ]))
}

pub(crate) fn list_sessions<D: EngineDriver>(shared: &Shared<D>) -> Result<Json, ApiError> {
    let st = shared.engine.lock().unwrap();
    let ids = st.sessions.ids();
    Ok(Json::obj(vec![
        ("count", Json::num(ids.len() as f64)),
        (
            "sessions",
            Json::Arr(ids.iter().map(|s| Json::num(s.0 as f64)).collect()),
        ),
    ]))
}

pub(crate) fn get_session<D: EngineDriver>(
    shared: &Shared<D>,
    sid: u64,
) -> Result<Json, ApiError> {
    let st = shared.engine.lock().unwrap();
    let s = st.sessions.get(SessionId(sid)).ok_or_else(|| {
        ApiError::not_found("session_not_found", format!("unknown session {sid}"))
    })?;
    let registry = st.engine.registry();
    Ok(Json::obj(vec![
        ("session", Json::num(sid as f64)),
        ("cache_salt", Json::str(s.cache_salt.to_string())),
        ("history_len", Json::num(s.history_len() as f64)),
        (
            "tokens",
            Json::Arr(s.tokens().iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("leased_blocks", Json::num(s.leased_blocks as f64)),
        ("in_flight", Json::Bool(s.in_flight().is_some())),
        (
            "turns",
            Json::Arr(s.turns().iter().map(|r| turn_json(registry, s.id, r)).collect()),
        ),
    ]))
}

pub(crate) fn delete_session<D: EngineDriver>(
    shared: &Shared<D>,
    sid: u64,
) -> Result<Json, ApiError> {
    let mut g = shared.engine.lock().unwrap();
    let st = &mut *g;
    let s = st
        .sessions
        .delete(&mut st.engine, SessionId(sid))
        .map_err(classify)?;
    st.engine.metrics_mut().sessions_closed += 1;
    Ok(Json::obj(vec![
        ("deleted", Json::num(sid as f64)),
        ("turns", Json::num(s.num_turns() as f64)),
        ("history_len", Json::num(s.history_len() as f64)),
    ]))
}

/// Non-streaming turn: submit the delta, wait for the driver thread,
/// apply the completion to the session, and return the turn summary.
pub(crate) fn run_turn<D: EngineDriver>(
    shared: &Shared<D>,
    sid: u64,
    t: TurnBody,
) -> Result<Json, ApiError> {
    let sid = SessionId(sid);
    let rid = submit_turn(shared, sid, &t, false)?;
    match wait_done(shared, rid) {
        Ok(out) => {
            let mut g = shared.engine.lock().unwrap();
            let st = &mut *g;
            match st.sessions.complete_turn(&mut st.engine, sid, &out) {
                Ok(rec) => Ok(turn_json(st.engine.registry(), sid, &rec)),
                Err(e) => {
                    // A completion the session cannot apply must still
                    // clear OUR in-flight turn — every error exit routes
                    // through an abort or the session 409s forever (the
                    // stuck-turn bug). Guarded on the id: failover repair
                    // may have aborted this turn already and a NEWER live
                    // turn must not be destroyed.
                    st.sessions.abort_turn_if(sid, rid);
                    Err(classify(e))
                }
            }
        }
        Err(e) => {
            // The request was orphaned by wait_done; detach the session's
            // pending turn (if it is still ours) so the conversation
            // stays usable.
            let mut st = shared.engine.lock().unwrap();
            st.sessions.abort_turn_if(sid, rid);
            Err(e)
        }
    }
}

/// Validate + submit a turn under the lock. `streaming` additionally
/// subscribes the request to turn events and installs its sink.
fn submit_turn<D: EngineDriver>(
    shared: &Shared<D>,
    sid: SessionId,
    t: &TurnBody,
    streaming: bool,
) -> Result<RequestId, ApiError> {
    let mut g = shared.engine.lock().unwrap();
    let st = &mut *g;
    // Unknown sessions surface from begin_turn, which classify() maps to
    // the 404 envelope — one translation point, no duplicate pre-check.
    let target = resolve_target(st.engine.registry(), t.adapter.as_deref())?;
    let (_turn, rid) = st
        .sessions
        .begin_turn(&mut st.engine, sid, target, t.tokens.clone(), t.max_new_tokens, t.append)
        .map_err(classify)?;
    if streaming {
        st.engine.watch(rid);
        st.streams.insert(rid, Vec::new());
    }
    shared.cv.notify_all();
    Ok(rid)
}

/// One wake-up's worth of a streaming turn wait.
enum TurnWait {
    Events(Vec<TurnEvent>),
    Fail(ApiError),
}

/// Streaming turn: chunked SSE — `started` (TTFT clock opens), one
/// `token` per generated token, then `finished` with the same summary the
/// non-streaming path returns (token sequences byte-identical by
/// construction: both come from the engine's single emission path).
pub(crate) fn stream_turn<D: EngineDriver>(
    stream: &mut TcpStream,
    shared: &Shared<D>,
    sid: u64,
    t: TurnBody,
) -> anyhow::Result<()> {
    let sid = SessionId(sid);
    let rid = match submit_turn(shared, sid, &t, true) {
        Ok(rid) => rid,
        // Nothing streamed yet: plain error response.
        Err(e) => return write_response(stream, e.status, "application/json", &e.body()),
    };
    // The finished output the streaming phase has seen but not yet
    // applied to the session — carried across a write failure so cleanup
    // can still commit a turn that genuinely completed server-side.
    let mut unapplied: Option<RequestOutput> = None;
    let result = stream_turn_events(stream, shared, sid, rid, &mut unapplied);
    if result.is_err() {
        // A socket write failed mid-stream (client went away). The
        // session must not stay wedged and nothing may leak: drop the
        // sink and subscription; if the turn actually finished (output in
        // hand, or still sitting undelivered in the sink), apply it —
        // only the client missed the final event. Otherwise detach the
        // turn and orphan the request so the driver discards its output
        // instead of parking it in `done` forever.
        let mut g = shared.engine.lock().unwrap();
        let st = &mut *g;
        if unapplied.is_none() {
            if let Some(sink) = st.streams.get(&rid) {
                unapplied = sink.iter().find_map(|ev| match ev {
                    TurnEvent::Finished { output, .. } => Some(output.clone()),
                    _ => None,
                });
            }
        }
        st.streams.remove(&rid);
        st.engine.unwatch(rid);
        let turn_pending =
            st.sessions.get(sid).map(|s| s.in_flight() == Some(rid)).unwrap_or(false);
        if turn_pending {
            match &unapplied {
                Some(out) => {
                    // Completed server-side: keep the history truthful.
                    let _ = st.sessions.complete_turn(&mut st.engine, sid, out);
                }
                None => {
                    // Still running: the driver must discard its output.
                    st.sessions.abort_turn_if(sid, rid);
                    st.orphaned.insert(rid);
                }
            }
        }
    }
    result
}

/// The streaming phase of a turn, from response headers to the terminal
/// chunk. Any `Err` here is a dead client socket — `stream_turn` cleans
/// up (using `unapplied`, the finished-but-not-yet-applied output, to
/// tell a completed turn from a still-running one); engine-side failures
/// are reported in-band as `error` events.
fn stream_turn_events<D: EngineDriver>(
    stream: &mut TcpStream,
    shared: &Shared<D>,
    sid: SessionId,
    rid: RequestId,
    unapplied: &mut Option<RequestOutput>,
) -> anyhow::Result<()> {
    start_stream(stream)?;
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    let mut finished: Option<RequestOutput> = None;
    'stream: while finished.is_none() {
        let step = {
            let mut g = shared.engine.lock().unwrap();
            loop {
                if g.failed.remove(&rid) {
                    // Failover rejected this request on every survivor:
                    // no more events will ever arrive (repair already
                    // aborted the session's turn).
                    let st = &mut *g;
                    st.streams.remove(&rid);
                    st.engine.unwatch(rid);
                    break TurnWait::Fail(ApiError::new(
                        "502 Bad Gateway",
                        "request_failed",
                        format!(
                            "turn request {rid:?} was lost to a replica failure and could not be requeued"
                        ),
                    ));
                }
                let Some(sink) = g.streams.get_mut(&rid) else {
                    break TurnWait::Fail(ApiError::new(
                        "500 Internal Server Error",
                        "internal",
                        "stream sink vanished",
                    ));
                };
                let events = std::mem::take(sink);
                if !events.is_empty() {
                    break TurnWait::Events(events);
                }
                let now = Instant::now();
                if now >= deadline {
                    let st = &mut *g;
                    st.streams.remove(&rid);
                    st.orphaned.insert(rid);
                    st.engine.unwatch(rid);
                    st.sessions.abort_turn_if(sid, rid);
                    break TurnWait::Fail(ApiError::timeout(format!(
                        "turn request {rid:?} timed out"
                    )));
                }
                let (guard, _) = shared.cv.wait_timeout(g, deadline - now).unwrap();
                g = guard;
            }
        };
        match step {
            TurnWait::Fail(e) => {
                write_sse(stream, "error", &e.event_json())?;
                return end_stream(stream);
            }
            TurnWait::Events(events) => {
                for ev in events {
                    match ev {
                        TurnEvent::Started { clock, arrival, .. } => {
                            write_sse(
                                stream,
                                "started",
                                &Json::obj(vec![
                                    ("session", Json::num(sid.0 as f64)),
                                    ("id", Json::num(rid.0 as f64)),
                                    ("t_s", Json::num(clock)),
                                    ("arrival_s", Json::num(arrival)),
                                    ("queue_s", Json::num(clock - arrival)),
                                ]),
                            )?;
                        }
                        TurnEvent::Token { index, token, clock, .. } => {
                            write_sse(
                                stream,
                                "token",
                                &Json::obj(vec![
                                    ("index", Json::num(index as f64)),
                                    ("token", Json::num(token as f64)),
                                    ("t_s", Json::num(clock)),
                                ]),
                            )?;
                        }
                        TurnEvent::Finished { output, .. } => {
                            *unapplied = Some(output.clone());
                            finished = Some(output);
                            continue 'stream; // falls out: finished is Some
                        }
                    }
                }
            }
        }
    }
    let out = finished.expect("loop exits only with an output");
    let reply = {
        let mut g = shared.engine.lock().unwrap();
        let st = &mut *g;
        st.streams.remove(&rid);
        let completed = st.sessions.complete_turn(&mut st.engine, sid, &out);
        match completed {
            Ok(rec) => {
                *unapplied = None; // applied: cleanup must not re-apply
                Ok(turn_json(st.engine.registry(), sid, &rec))
            }
            Err(e) => {
                // Unapplicable completion: clear OUR in-flight turn so the
                // session keeps accepting turns (stuck-409 bugfix; id
                // guard protects a newer turn), and stop the cleanup path
                // from retrying the same apply.
                st.sessions.abort_turn_if(sid, rid);
                *unapplied = None;
                Err(classify(e))
            }
        }
    };
    match reply {
        Ok(j) => write_sse(stream, "finished", &j)?,
        Err(e) => write_sse(stream, "error", &e.event_json())?,
    }
    end_stream(stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn turn_body_parsing_defaults_and_rejections() {
        let j = Json::parse(r#"{"tokens": [1,2,3]}"#).unwrap();
        let t = parse_turn(&j).unwrap();
        assert_eq!(t.tokens, vec![1, 2, 3]);
        assert_eq!(t.max_new_tokens, 16);
        assert!(t.append && !t.stream);
        assert!(t.adapter.is_none());

        let j = Json::parse(
            r#"{"tokens": [], "adapter": "alora-0", "max_new_tokens": 4,
                "append": false, "stream": true}"#,
        )
        .unwrap();
        let t = parse_turn(&j).unwrap();
        assert_eq!(t.adapter.as_deref(), Some("alora-0"));
        assert_eq!(t.max_new_tokens, 4);
        assert!(!t.append && t.stream);

        // Null adapter is base; typed garbage is rejected.
        let j = Json::parse(r#"{"tokens": [1], "adapter": null}"#).unwrap();
        assert!(parse_turn(&j).unwrap().adapter.is_none());
        let j = Json::parse(r#"{"tokens": [1], "adapter": 3}"#).unwrap();
        assert!(parse_turn(&j).is_err());
        let j = Json::parse(r#"{"tokens": "nope"}"#).unwrap();
        assert!(parse_turn(&j).is_err());
    }
}
