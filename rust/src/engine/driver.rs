//! The uniform driving interface over "something that serves requests".
//!
//! Before the cluster existed, the coordinator, the pipeline drivers and
//! the HTTP server all called `Engine<E>`'s concrete methods, so every
//! higher layer was hard-wired to exactly one replica. [`EngineDriver`]
//! extracts that surface — submit / step / clock / completion-drain /
//! metrics — so the same coordinator code drives a single [`Engine`] or a
//! [`crate::cluster::Cluster`] of N replicas behind a router. Child stages
//! of a conversation then inherit their parent's replica affinity for
//! free: the cluster's `PrefixAffinity` policy routes each follow-up to
//! whichever replica already committed the parent's base-aligned blocks.
//!
//! Semantics every implementor must honor:
//! - `clock` is virtual seconds and monotonic; for a fleet it is the
//!   *makespan* clock (max over replicas — replicas run in parallel).
//! - `step` returns false only when nothing was schedulable anywhere.
//! - `take_finished*` transfers ownership of finished outputs exactly once.

use crate::adapter::AdapterRegistry;
use crate::config::EngineConfig;
use crate::engine::{Engine, Executor};
use crate::kvcache::chain::ChainRef;
use crate::metrics::Metrics;
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams, TurnEvent};

pub trait EngineDriver {
    /// Submit with queue priority and a multi-tenant cache salt — the one
    /// required submission entrypoint; the convenience forms default to it.
    fn submit_salted(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
    ) -> anyhow::Result<RequestId>;

    /// Submit a conversation follow-up that should land wherever `peer`
    /// (the conversation's previous request) ran — session stickiness. A
    /// single engine has nowhere else to go, so the default ignores the
    /// peer; a cluster overrides to pin the turn to the replica holding
    /// the session's prefix (falling back to its routing policy when
    /// `peer` is None, i.e. a first turn).
    fn submit_sticky(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
        peer: Option<RequestId>,
    ) -> anyhow::Result<RequestId> {
        let _ = peer;
        self.submit_salted(target, prompt, params, priority, cache_salt)
    }

    /// [`EngineDriver::submit_sticky`] with the prompt's block-hash chain
    /// already computed. The session layer caches each conversation's
    /// chain and extends it O(delta tokens) per turn; passing it here
    /// lets routing and admission skip rehashing the whole history.
    /// `lease` names the session's prefix lease so a re-routing cluster
    /// can read the incrementally-maintained affinity of the replica
    /// pinning it instead of probing. The chain is trusted (it must come
    /// from the driver's own salting context — see
    /// `Engine::submit_prehashed`); drivers without a prehashed path
    /// simply drop both hints.
    #[allow(clippy::too_many_arguments)]
    fn submit_sticky_prehashed(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
        peer: Option<RequestId>,
        lease: Option<u64>,
        chain: ChainRef,
    ) -> anyhow::Result<RequestId> {
        let _ = (lease, chain);
        self.submit_sticky(target, prompt, params, priority, cache_salt, peer)
    }

    /// Subscribe to per-request [`TurnEvent`]s (streaming turns). The
    /// default is a no-op: drivers without an event surface simply never
    /// deliver events (and [`EngineDriver::take_events`] stays empty).
    fn watch(&mut self, id: RequestId) {
        let _ = id;
    }

    /// Cancel a subscription (streaming client went away).
    fn unwatch(&mut self, id: RequestId) {
        let _ = id;
    }

    /// Drain events emitted for watched requests since the last drain —
    /// the incremental per-step intake a streaming server consumes.
    fn take_events(&mut self) -> Vec<TurnEvent> {
        Vec::new()
    }

    /// Pin the cached prefix of a conversation's token stream under
    /// `lease` so it survives between turns. `peer` names the replica
    /// that holds the blocks (the turn that just ran there); clusters
    /// route on it, single engines ignore it. Returns blocks pinned
    /// (default: 0 — no retention surface).
    fn acquire_lease(
        &mut self,
        lease: u64,
        tokens: &[u32],
        cache_salt: u64,
        peer: Option<RequestId>,
    ) -> usize {
        let _ = (lease, tokens, cache_salt, peer);
        0
    }

    /// [`EngineDriver::acquire_lease`] with the chain already hashed
    /// (base context + salt — what the session layer's cached chain
    /// holds). Returns total blocks pinned under the lease (default: 0 —
    /// no retention surface).
    fn acquire_lease_prehashed(
        &mut self,
        lease: u64,
        chain: &ChainRef,
        peer: Option<RequestId>,
    ) -> usize {
        let _ = (lease, chain, peer);
        0
    }

    /// Release a prefix lease everywhere it might live (session deleted).
    fn release_lease(&mut self, lease: u64) {
        let _ = lease;
    }

    /// Ship a leased chain's blocks to wherever `peer` (the session's
    /// latest request) now lives, instead of letting the next turn
    /// recompute the prefix from token zero (DESIGN.md §18). Only a
    /// multi-replica cluster with `cache.prefix_migration` enabled has
    /// anywhere to ship to — and even then the migrate-vs-recompute cost
    /// model may decline — so the default is the universal fallback:
    /// migrate nothing, recompute as before. Returns blocks installed at
    /// the destination (0 = recompute path).
    fn migrate_lease(&mut self, lease: u64, chain: &ChainRef, peer: Option<RequestId>) -> usize {
        let _ = (lease, chain, peer);
        0
    }

    /// Count session forks (`POST /v1/sessions/{id}/fork`); the fleet
    /// owns the `session_forks_total` counter. No-op off-cluster, like
    /// [`EngineDriver::note_resticks`].
    fn note_session_forks(&mut self, n: u64) {
        let _ = n;
    }

    fn submit_with_priority(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
    ) -> anyhow::Result<RequestId> {
        self.submit_salted(target, prompt, params, priority, 0)
    }

    fn submit(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
    ) -> anyhow::Result<RequestId> {
        self.submit_salted(target, prompt, params, false, 0)
    }

    /// Drive one step; false = nothing schedulable (caller advances the
    /// clock to the next arrival or stops).
    fn step(&mut self) -> bool;

    fn clock(&self) -> f64;

    /// Advance the virtual clock (never backwards).
    fn advance_clock_to(&mut self, t: f64);

    fn has_work(&self) -> bool;

    fn num_waiting(&self) -> usize;

    fn num_running(&self) -> usize;

    /// Drain all finished request records (ownership transferred).
    fn take_finished(&mut self) -> Vec<RequestOutput>;

    /// Finished-but-undrained count (completion-drain polling).
    fn finished_pending(&self) -> usize;

    /// Drain only the finished outputs `pred` selects, leaving the rest
    /// for whoever owns them (the coordinator's completion intake).
    fn take_finished_where<F: FnMut(&RequestOutput) -> bool>(
        &mut self,
        pred: F,
    ) -> Vec<RequestOutput>;

    /// Driver-level metrics: where the coordinator records per-stage
    /// series. For a cluster this is the fleet registry, not a replica's.
    fn metrics(&self) -> &Metrics;

    fn metrics_mut(&mut self) -> &mut Metrics;

    /// The engine configuration (identical across a cluster's replicas).
    fn config(&self) -> &EngineConfig;

    /// The adapter registry (identical across a cluster's replicas).
    fn registry(&self) -> &AdapterRegistry;

    /// Prometheus exposition for `/metrics`. Clusters override to add
    /// per-replica labeled families and routing counters.
    fn render_prometheus(&self) -> String {
        self.metrics().render_prometheus()
    }

    /// Fleet stats for `GET /cluster`. The default is None; `Engine`
    /// overrides with a one-replica document (API consistency: a
    /// single-engine server reports a fleet of one instead of 404) and
    /// `Cluster` with the real fleet snapshot.
    fn cluster_stats(&self) -> Option<crate::cluster::ClusterStats> {
        None
    }

    /// Replica administration (`POST /cluster/replicas/{i}/fail`): mark a
    /// replica failed, requeue its work onto survivors, orphan its
    /// leases. Only a fleet can do this; the single-engine default
    /// refuses (there is no survivor to requeue onto).
    fn fail_replica(&mut self, i: usize) -> anyhow::Result<crate::cluster::FailoverReport> {
        anyhow::bail!("no fleet: replica {i} administration needs a multi-replica cluster")
    }

    /// Stop placing new work on a replica while it finishes what it has.
    fn drain_replica(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::bail!("no fleet: replica {i} administration needs a multi-replica cluster")
    }

    /// Return a failed or draining replica to rotation.
    fn restore_replica(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::bail!("no fleet: replica {i} administration needs a multi-replica cluster")
    }

    /// Fault injection (`POST /cluster/replicas/{i}/silence`): stop a
    /// replica's heartbeats and gossip while it keeps its state and its
    /// work — a network partition the failure detector must notice
    /// (DESIGN.md §19). Only meaningful on a fleet.
    fn silence_replica(&mut self, i: usize) -> anyhow::Result<()> {
        anyhow::bail!("no fleet: replica {i} administration needs a multi-replica cluster")
    }

    /// Failovers the fleet's failure detector ran on its own (no admin
    /// call). The serving layer drains these once per driver step and
    /// applies the same session repair an operator-declared failure
    /// gets. Empty off-cluster.
    fn take_failover_reports(&mut self) -> Vec<crate::cluster::FailoverReport> {
        Vec::new()
    }

    /// The `GET /cluster/health` document: the failure detector's view
    /// of every replica. None off-cluster (a single engine has no
    /// detector; the endpoint 404s).
    fn cluster_health(&self) -> Option<crate::util::json::Json> {
        None
    }

    /// Count conversations whose stickiness the serving layer cleared
    /// during failover repair (the sessions re-stick on their next turn;
    /// the fleet owns the `resticks_total` counter). No-op off-cluster.
    fn note_resticks(&mut self, n: u64) {
        let _ = n;
    }

    /// Run until every submitted request has finished; panics on stall
    /// (request too large for capacity) rather than spinning.
    fn run_until_idle(&mut self) {
        while self.has_work() {
            if !self.step() {
                panic!(
                    "driver stalled: {} waiting / {} running but nothing schedulable",
                    self.num_waiting(),
                    self.num_running()
                );
            }
        }
    }
}

impl<E: Executor> EngineDriver for Engine<E> {
    fn submit_salted(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
    ) -> anyhow::Result<RequestId> {
        Engine::submit_salted(self, target, prompt, params, priority, cache_salt)
    }

    fn step(&mut self) -> bool {
        Engine::step(self)
    }

    fn clock(&self) -> f64 {
        Engine::clock(self)
    }

    fn advance_clock_to(&mut self, t: f64) {
        Engine::advance_clock_to(self, t)
    }

    fn has_work(&self) -> bool {
        Engine::has_work(self)
    }

    fn num_waiting(&self) -> usize {
        Engine::num_waiting(self)
    }

    fn num_running(&self) -> usize {
        Engine::num_running(self)
    }

    fn take_finished(&mut self) -> Vec<RequestOutput> {
        Engine::take_finished(self)
    }

    fn finished_pending(&self) -> usize {
        Engine::finished_pending(self)
    }

    fn take_finished_where<F: FnMut(&RequestOutput) -> bool>(
        &mut self,
        pred: F,
    ) -> Vec<RequestOutput> {
        Engine::take_finished_where(self, pred)
    }

    fn watch(&mut self, id: RequestId) {
        Engine::watch(self, id)
    }

    fn unwatch(&mut self, id: RequestId) {
        Engine::unwatch(self, id)
    }

    fn take_events(&mut self) -> Vec<TurnEvent> {
        Engine::take_events(self)
    }

    fn submit_sticky_prehashed(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
        _peer: Option<RequestId>,
        _lease: Option<u64>,
        chain: ChainRef,
    ) -> anyhow::Result<RequestId> {
        Engine::submit_prehashed(self, target, prompt, params, priority, cache_salt, chain)
    }

    fn acquire_lease(
        &mut self,
        lease: u64,
        tokens: &[u32],
        cache_salt: u64,
        _peer: Option<RequestId>,
    ) -> usize {
        Engine::lease_prefix(self, lease, tokens, cache_salt)
    }

    fn acquire_lease_prehashed(
        &mut self,
        lease: u64,
        chain: &ChainRef,
        _peer: Option<RequestId>,
    ) -> usize {
        Engine::lease_prefix_prehashed(self, lease, chain)
    }

    fn release_lease(&mut self, lease: u64) {
        Engine::release_prefix_lease(self, lease)
    }

    fn cluster_stats(&self) -> Option<crate::cluster::ClusterStats> {
        Some(crate::cluster::single_engine_stats(self))
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn registry(&self) -> &AdapterRegistry {
        &self.registry
    }

    fn run_until_idle(&mut self) {
        Engine::run_until_idle(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterRegistry;
    use crate::config::presets;
    use crate::simulator::SimExecutor;

    /// Generic driver code must behave identically to direct engine calls.
    fn drive<D: EngineDriver>(d: &mut D) -> Vec<RequestOutput> {
        let id = d
            .submit(ModelTarget::Base, (0..40).collect(), SamplingParams::default())
            .unwrap();
        d.run_until_idle();
        let outs = d.take_finished();
        assert!(outs.iter().any(|o| o.id == id));
        outs
    }

    #[test]
    fn engine_drives_through_the_trait() {
        let cfg = presets::tiny();
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        let mut e = Engine::with_registry(cfg.clone(), reg, SimExecutor::new(&cfg));
        let outs = drive(&mut e);
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].output_tokens.len(), 16);
        assert_eq!(EngineDriver::metrics(&e).requests_finished, 1);
        assert_eq!(e.config().model.name, "tiny");
        assert_eq!(EngineDriver::registry(&e).len(), 3);
        // A single engine reports a one-replica fleet document (the
        // `GET /cluster` consistency satellite), not None.
        let cs = e.cluster_stats().expect("single-engine stats");
        assert_eq!(cs.policy, "single");
        assert_eq!(cs.replicas.len(), 1);
        assert_eq!(cs.replicas[0].finished, 1);
        assert_eq!(cs.routing.routed, vec![1]);
    }

    #[test]
    fn tenant_salts_partition_the_prefix_cache() {
        let cfg = presets::tiny();
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        let mut e = Engine::with_registry(cfg.clone(), reg, SimExecutor::new(&cfg));
        let prompt: Vec<u32> = (0..64).collect();
        let p = SamplingParams { max_new_tokens: 4, ..Default::default() };
        let a = e
            .submit_salted(ModelTarget::Base, prompt.clone(), p, false, 111)
            .unwrap();
        let a_out = e.run_to_completion(a);
        assert_eq!(a_out.num_cached_tokens, 0);
        // Different tenant, identical prompt: must NOT hit tenant A's blocks.
        let b = e
            .submit_salted(ModelTarget::Base, prompt.clone(), p, false, 222)
            .unwrap();
        assert_eq!(e.run_to_completion(b).num_cached_tokens, 0, "cross-tenant hit");
        // Same tenant again: full reuse of its own prefix.
        let a2 = e
            .submit_salted(ModelTarget::Base, prompt, p, false, 111)
            .unwrap();
        assert_eq!(e.run_to_completion(a2).num_cached_tokens, 48);
    }
}
