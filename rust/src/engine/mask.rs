//! Activation-aware batch mask (paper Algorithm 1 + Appendix A/B).
//!
//! Before each forward pass the GPU model runner prepares one flat mask
//! over every scheduled token in the batch: `true` = the token precedes its
//! request's aLoRA activation point, so the QKV projection must use frozen
//! base weights (which is what keeps pre-activation K/V base-identical).
//! Invocation points vary per request within a batch; the mask unifies them
//! into a single tensor so the model forward needs no per-request dispatch
//! — exactly the vLLM-side design the paper describes.

use crate::util::fxmap::FxHashMap;

use crate::request::{Request, RequestId};
use crate::scheduler::ScheduledSeq;

/// Flat per-token mask + per-sequence spans for one scheduled step.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct BatchMask {
    /// One entry per scheduled token, in seq order then position order.
    /// `true` = pre-activation (base weights).
    pub mask_pre: Vec<bool>,
    /// (request, offset into mask_pre, len) per scheduled sequence.
    pub spans: Vec<(RequestId, usize, usize)>,
}

impl BatchMask {
    /// Slice of the mask belonging to one request's chunk.
    pub fn span_of(&self, id: RequestId) -> Option<&[bool]> {
        self.spans
            .iter()
            .find(|(r, _, _)| *r == id)
            .map(|&(_, off, len)| &self.mask_pre[off..off + len])
    }

    pub fn total_tokens(&self) -> usize {
        self.mask_pre.len()
    }
}

/// Build the mask for a scheduled step (mirrors `build_alora_metadata` in
/// the paper's Appendix B: `position_within_req < inv_start[req]`).
pub fn build_batch_mask(
    seqs: &[ScheduledSeq],
    reqs: &FxHashMap<RequestId, Request>,
) -> BatchMask {
    let total: usize = seqs.iter().map(|s| s.chunk_len).sum();
    let mut mask_pre = Vec::with_capacity(total);
    let mut spans = Vec::with_capacity(seqs.len());
    for s in seqs {
        let r = &reqs[&s.id];
        let off = mask_pre.len();
        // `activation_start` is prompt_len for base requests (everything
        // pre), 0 for standard LoRA (everything adapted), and the
        // invocation index for aLoRA.
        let inv = r.activation_start;
        for p in s.chunk_start..s.chunk_start + s.chunk_len {
            mask_pre.push(p < inv);
        }
        spans.push((s.id, off, s.chunk_len));
    }
    BatchMask { mask_pre, spans }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{ModelTarget, SamplingParams};

    fn req_with_activation(id: u64, prompt_len: usize, inv: usize) -> Request {
        let mut r = Request::new(
            RequestId(id),
            ModelTarget::Base,
            (0..prompt_len as u32).collect(),
            SamplingParams::default(),
            0.0,
        );
        r.activation_start = inv;
        r
    }

    fn seq(id: u64, start: usize, len: usize) -> ScheduledSeq {
        ScheduledSeq {
            id: RequestId(id),
            chunk_start: start,
            chunk_len: len,
            produces_token: false,
            is_decode: false,
        }
    }

    #[test]
    fn mask_isolates_pre_activation_tokens() {
        let mut reqs = FxHashMap::default();
        reqs.insert(RequestId(1), req_with_activation(1, 10, 6));
        let m = build_batch_mask(&[seq(1, 0, 10)], &reqs);
        assert_eq!(
            m.mask_pre,
            vec![true, true, true, true, true, true, false, false, false, false]
        );
    }

    #[test]
    fn heterogeneous_invocation_points_in_one_batch() {
        // Paper Appendix B: "the actual aLoRA mask covers all requests in a
        // batch simultaneously and accounts for varying points of
        // invocation."
        let mut reqs = FxHashMap::default();
        reqs.insert(RequestId(1), req_with_activation(1, 8, 4)); // aLoRA @4
        reqs.insert(RequestId(2), req_with_activation(2, 8, 0)); // LoRA
        reqs.insert(RequestId(3), req_with_activation(3, 8, 8)); // base
        let m = build_batch_mask(&[seq(1, 0, 8), seq(2, 0, 8), seq(3, 0, 8)], &reqs);
        assert_eq!(m.total_tokens(), 24);
        assert_eq!(m.span_of(RequestId(1)).unwrap()[3], true);
        assert_eq!(m.span_of(RequestId(1)).unwrap()[4], false);
        assert!(m.span_of(RequestId(2)).unwrap().iter().all(|&b| !b));
        assert!(m.span_of(RequestId(3)).unwrap().iter().all(|&b| b));
    }

    #[test]
    fn chunk_offsets_respect_absolute_positions() {
        // A chunk starting mid-request uses absolute token positions, so a
        // cache-extension prefill after the activation point is all-post.
        let mut reqs = FxHashMap::default();
        reqs.insert(RequestId(1), req_with_activation(1, 64, 40));
        let m = build_batch_mask(&[seq(1, 40, 8)], &reqs);
        assert!(m.span_of(RequestId(1)).unwrap().iter().all(|&b| !b));
        let m = build_batch_mask(&[seq(1, 32, 8)], &reqs);
        assert!(m.span_of(RequestId(1)).unwrap().iter().all(|&b| b));
    }

    #[test]
    fn decode_token_masked_by_position() {
        let mut reqs = FxHashMap::default();
        reqs.insert(RequestId(1), req_with_activation(1, 16, 16));
        // decode at position 20 (>= inv 16): adapted
        let m = build_batch_mask(
            &[ScheduledSeq {
                id: RequestId(1),
                chunk_start: 20,
                chunk_len: 1,
                produces_token: true,
                is_decode: true,
            }],
            &reqs,
        );
        assert_eq!(m.mask_pre, vec![false]);
    }
}
