//! The serving engine core loop (vLLM Figure 2, rust edition).
//!
//! `Engine<E: Executor>` owns the scheduler, the KV-cache manager, the
//! adapter registry, the clock, and metrics. One `step()`:
//!
//! 1. scheduler packs a batch (continuous batching + chunked prefill,
//!    consulting the base-aligned prefix cache at admission),
//! 2. the activation-aware [`mask::BatchMask`] is built for the batch,
//! 3. the executor runs the batch — either the H100 cost-model simulator
//!    or the real PJRT CPU runtime; both return elapsed virtual seconds,
//! 4. progress, block-hash commits, lifecycle timestamps and metrics are
//!    applied.
//!
//! The clock is *virtual*: the simulator advances it by modeled GPU time,
//! the real executor by measured wall time, so Table-2 metrics come out of
//! the same pipeline either way.

pub mod driver;
pub mod mask;

use crate::util::fxmap::{FxHashMap, FxHashSet};

use crate::adapter::{AdapterId, AdapterRegistry, AdapterResidency};
use crate::config::EngineConfig;
use crate::kvcache::chain::ChainRef;
use crate::kvcache::manager::KvCacheManager;
use crate::kvcache::prefix::{block_hashes, next_block_hash};
use crate::metrics::Metrics;
use crate::request::{
    ModelTarget, Request, RequestId, RequestOutput, SamplingParams, State, TurnEvent,
};
use crate::scheduler::{ScheduledStep, Scheduler};

pub use driver::EngineDriver;
pub use mask::{build_batch_mask, BatchMask};

/// A request pulled off a failed (or failing) replica, carrying exactly
/// what a survivor needs to re-run it under the SAME fleet-unique id:
/// callers blocked on the [`RequestId`] still get their output, the
/// arrival timestamp keeps queue-time accounting honest (the failover
/// delay shows up as queue time, not as a vanished request), and the
/// watch flag re-subscribes streaming turns on the new replica (which
/// re-emits `Started`/`Token` events — generation restarts from scratch,
/// exactly like a recompute preemption).
#[derive(Debug, Clone)]
pub struct EvacuatedRequest {
    pub id: RequestId,
    pub target: ModelTarget,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    pub cache_salt: u64,
    pub arrival: f64,
    pub preemptions: u32,
    pub watched: bool,
}

/// Result of executing one scheduled step.
#[derive(Debug, Clone, Default)]
pub struct StepResult {
    /// Virtual seconds the step took (model time, not coordinator time).
    pub elapsed: f64,
    /// Sampled next token for every sequence that produced one this step.
    /// Sequences missing here default to token 0 (simulator executors
    /// don't model token values — paper §4.1: values don't affect speed).
    pub sampled: Vec<(RequestId, u32)>,
}

/// A model-execution backend: the discrete-event simulator or the real
/// PJRT runtime. Implementations receive the full scheduled step, request
/// states and the activation-aware batch mask.
pub trait Executor {
    fn execute(
        &mut self,
        step: &ScheduledStep,
        reqs: &FxHashMap<RequestId, Request>,
        kv: &KvCacheManager,
        mask: &BatchMask,
    ) -> StepResult;
}

pub struct Engine<E: Executor> {
    pub cfg: EngineConfig,
    pub registry: AdapterRegistry,
    pub metrics: Metrics,
    exec: E,
    sched: Scheduler,
    kv: KvCacheManager,
    /// Adapter-weight residency, paged against the KV block budget when
    /// `cfg.cache.adapter_paging` is on (always-resident stub otherwise).
    residency: AdapterResidency,
    reqs: FxHashMap<RequestId, Request>,
    clock: f64,
    next_id: u64,
    /// Request-id increment. 1 standalone; a cluster partitions the id
    /// space across replicas (replica i issues i, i+n, i+2n, ...) so
    /// outputs carry fleet-unique ids without translation.
    id_stride: u64,
    finished: Vec<RequestOutput>,
    /// Requests subscribed to [`TurnEvent`] emission (streaming turns).
    /// Unwatched requests pay nothing: no events are buffered for them.
    watched: FxHashSet<RequestId>,
    /// Events emitted since the last [`Engine::take_events`] drain. The
    /// finish bookkeeping runs through [`Engine::emit_finish`], so the
    /// `finished` ledger and the event stream are fed by one path.
    events: Vec<TurnEvent>,
}

impl<E: Executor> Engine<E> {
    pub fn new(cfg: EngineConfig, exec: E) -> Self {
        Self::with_registry(cfg, AdapterRegistry::new(), exec)
    }

    pub fn with_registry(cfg: EngineConfig, registry: AdapterRegistry, exec: E) -> Self {
        cfg.validate().expect("invalid engine config");
        let mut kv = KvCacheManager::new(
            cfg.cache.num_blocks() as u32,
            cfg.cache.block_size,
            cfg.cache.enable_prefix_caching,
        );
        kv.set_host_adapter_blocks(cfg.cache.host_adapter_blocks as usize);
        let sched = Scheduler::new(cfg.scheduler.clone());
        let mut residency = AdapterResidency::new(
            &registry,
            &cfg.model,
            cfg.cache.block_size,
            cfg.cache.adapter_paging,
        );
        // Transfer-cost scalars for the residency state machine — the
        // same per-block figure `CostModel::adapter_load_time` models
        // (kv_bytes/token × block_size / host→device bandwidth). Zero
        // bandwidth (the default) keeps loads instantaneous.
        let (setup_s, per_block_s) = if cfg.cache.adapter_load_bw > 0.0 {
            (
                cfg.cache.adapter_load_setup,
                cfg.model.kv_bytes_per_token() * cfg.cache.block_size as f64
                    / cfg.cache.adapter_load_bw,
            )
        } else {
            (0.0, 0.0)
        };
        residency.configure_tiering(setup_s, per_block_s, cfg.cache.adapter_prefetch);
        Engine {
            kv,
            sched,
            residency,
            registry,
            exec,
            reqs: FxHashMap::default(),
            clock: 0.0,
            next_id: 0,
            id_stride: 1,
            metrics: Metrics::new(),
            finished: Vec::new(),
            watched: FxHashSet::default(),
            events: Vec::new(),
            cfg,
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Advance the virtual clock (used by async drivers between arrivals).
    /// Panics on attempts to move time backwards.
    pub fn advance_clock_to(&mut self, t: f64) {
        assert!(t >= self.clock, "clock must be monotonic ({t} < {})", self.clock);
        self.clock = t;
    }

    pub fn num_waiting(&self) -> usize {
        self.sched.num_waiting()
    }

    pub fn num_running(&self) -> usize {
        self.sched.num_running()
    }

    pub fn has_work(&self) -> bool {
        self.sched.has_work()
    }

    pub fn kv_stats(&self) -> crate::kvcache::manager::CacheStats {
        self.kv.stats()
    }

    pub fn num_free_blocks(&self) -> u32 {
        self.kv.num_free_blocks()
    }

    pub fn num_total_blocks(&self) -> u32 {
        self.kv.num_total_blocks()
    }

    /// Routable view of this engine's committed KV hashes (what a cluster
    /// router scores prefix affinity against).
    pub fn routing_summary(&self) -> &crate::kvcache::summary::HashSummary {
        self.kv.routing_summary()
    }

    /// Adapter-weight residency state (loads, evictions, resident set).
    pub fn residency(&self) -> &AdapterResidency {
        &self.residency
    }

    /// The unified memory ledger (KV pages vs resident adapter weights).
    pub fn memory_budget(&self) -> &crate::memory::MemoryBudget {
        self.kv.budget()
    }

    /// Blocks currently pinned by session prefix leases.
    pub fn leased_blocks(&self) -> usize {
        self.kv.leased_blocks()
    }

    /// Active session prefix leases.
    pub fn num_leases(&self) -> usize {
        self.kv.num_leases()
    }

    /// Weight pages of `aid` already resident here — the router's
    /// adapter-affinity term (0 when non-resident or paging is off: with
    /// always-resident weights every replica scores alike).
    pub fn adapter_affinity_blocks(&self, aid: AdapterId) -> usize {
        if self.residency.enabled() && self.residency.is_resident(aid) {
            self.residency.weight_blocks_of(aid)
        } else {
            0
        }
    }

    /// True while no request has ever been submitted and no id namespace
    /// applied — the state [`crate::cluster::Cluster`] requires of the
    /// replicas it wraps (fallible constructors check this instead of
    /// tripping [`Engine::set_id_namespace`]'s assert).
    pub fn is_fresh(&self) -> bool {
        self.next_id == 0 && self.id_stride == 1 && self.reqs.is_empty()
    }

    /// Partition the request-id space for cluster membership: this engine
    /// will issue ids `start, start + stride, ...`. Must be called before
    /// any submission — replica ids are a construction-time property.
    pub fn set_id_namespace(&mut self, start: u64, stride: u64) {
        assert!(stride > 0, "zero id stride");
        assert!(self.is_fresh(), "id namespace must be set before any submission");
        self.next_id = start;
        self.id_stride = stride;
    }

    pub fn executor(&self) -> &E {
        &self.exec
    }

    pub fn executor_mut(&mut self) -> &mut E {
        &mut self.exec
    }

    /// Submit a request arriving *now* (at the current virtual clock).
    pub fn submit(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
    ) -> anyhow::Result<RequestId> {
        self.submit_with_priority(target, prompt, params, false)
    }

    /// Like [`submit`](Self::submit), but `priority = true` enqueues at the
    /// FRONT of the waiting queue. Used for conversation continuations
    /// (adapter evaluations, follow-up base turns): admitting them before
    /// newly arrived conversations harvests their still-cached prefixes
    /// before eviction can claim the blocks (paper §4.3's load-management
    /// point; see `figures::ablations::watermark_sweep`).
    pub fn submit_with_priority(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
    ) -> anyhow::Result<RequestId> {
        self.submit_salted(target, prompt, params, priority, 0)
    }

    /// Full submission form: adds the multi-tenant `cache_salt` (vLLM
    /// semantics: nonzero salts partition the prefix cache so tenants can
    /// never hit each other's blocks; 0 = unsalted shared cache).
    pub fn submit_salted(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
    ) -> anyhow::Result<RequestId> {
        self.submit_prehashed(target, prompt, params, priority, cache_salt, ChainRef::empty())
    }

    /// Like [`submit_salted`](Self::submit_salted), pre-seeding the
    /// request's block-hash chain. The cluster router already hashed the
    /// prompt's chain to score replica affinity; admission reuses it
    /// instead of rehashing (chain entries are deterministic in
    /// (tokens, salting context), so the scheduler rebuilds only when the
    /// token stream has outgrown the chain). Pass an empty chain to hash
    /// lazily at admission.
    ///
    /// Crate-private on purpose: the chain's *content* is trusted (only
    /// its length is checked, and only in debug builds), so a caller
    /// passing a chain hashed under a different salt or prompt could
    /// alias another tenant's blocks. The cluster router derives its
    /// chain from the same `request_hash_context` as this method, which
    /// is what makes the trust sound.
    pub(crate) fn submit_prehashed(
        &mut self,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: bool,
        cache_salt: u64,
        chain: ChainRef,
    ) -> anyhow::Result<RequestId> {
        let id = RequestId(self.next_id);
        let req =
            self.prepare_request(id, target, prompt, params, self.clock, cache_salt, chain)?;
        self.next_id += self.id_stride;
        self.admit_prepared(req, priority);
        Ok(id)
    }

    /// Validate a submission and build its [`Request`] without touching
    /// engine state — the shared front half of [`Self::submit_prehashed`]
    /// and [`Self::submit_evacuated`] (failover requeue reuses every check
    /// but supplies its own id and arrival).
    fn prepare_request(
        &self,
        id: RequestId,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        arrival: f64,
        cache_salt: u64,
        chain: ChainRef,
    ) -> anyhow::Result<Request> {
        let final_len = prompt.len() + params.max_new_tokens as usize;
        anyhow::ensure!(
            final_len <= self.cfg.scheduler.max_seq_len as usize,
            "request length {final_len} exceeds max_seq_len {}",
            self.cfg.scheduler.max_seq_len
        );
        anyhow::ensure!(
            final_len as u64 <= self.cfg.cache.max_kv_tokens,
            "request length {final_len} exceeds KV capacity"
        );
        // Unified budget: an adapter request additionally needs its weight
        // pages co-resident with its KV for the whole run. Reject up front
        // what could never be admitted, instead of stalling forever.
        if let (true, Some(aid)) = (self.residency.enabled(), target.adapter()) {
            let weight = self.residency.weight_blocks_of(aid);
            let kv_demand = final_len.div_ceil(self.cfg.cache.block_size as usize);
            anyhow::ensure!(
                weight + kv_demand <= self.kv.num_total_blocks() as usize,
                "request needs {kv_demand} KV blocks + {weight} adapter-weight \
                 blocks, exceeding the {}-block device budget",
                self.kv.num_total_blocks()
            );
        }
        let mut req = Request::new(id, target, prompt, params, arrival);

        // Activation scan + salting policy, shared with the cluster router
        // (AdapterRegistry::request_hash_context is the single source of
        // truth so routing chains stay byte-identical to admission's).
        let (activation_start, hash_ctx) = self
            .registry
            .request_hash_context(
                target.adapter(),
                &req.prompt,
                self.cfg.cache.base_aligned_hashing,
                cache_salt,
            )
            .ok_or_else(|| {
                // None is only reachable for an adapter target.
                let aid = target.adapter().expect("base target cannot be unknown");
                anyhow::anyhow!("unknown adapter {aid:?}")
            })?;
        req.activation_start = activation_start;
        req.hash_ctx = hash_ctx;
        debug_assert!(
            chain.is_empty()
                || chain.len() == req.prompt.len() / self.cfg.cache.block_size as usize,
            "pre-seeded chain must cover exactly the prompt's full blocks"
        );
        req.hash_chain = chain;
        Ok(req)
    }

    /// The back half of submission: counters + ledger + queue.
    fn admit_prepared(&mut self, req: Request, priority: bool) {
        let id = req.id;
        self.metrics.requests_received += 1;
        self.metrics.prompt_tokens += req.prompt.len() as u64;
        self.reqs.insert(id, req);
        self.sched.enqueue(id, priority);
    }

    /// Pull every queued request (running and waiting) off this engine for
    /// requeue elsewhere — the first half of replica failover. Running
    /// requests lose their KV and adapter refs (the device died; the
    /// survivor recomputes, like a preemption), buffered turn events for
    /// them are dropped (the new replica re-emits), and their
    /// received/prompt-token counters are rolled back so the fleet
    /// aggregate counts each request exactly once after the survivor
    /// re-counts it. Finished-but-undrained outputs are NOT touched: the
    /// completion ledger lives at the serving layer and survives the
    /// compute failure. Order: running (admission order) then waiting
    /// (queue order) — overall FCFS.
    pub fn evacuate_requests(&mut self) -> Vec<EvacuatedRequest> {
        let (running, waiting) = self.sched.drain_all();
        let mut out = Vec::with_capacity(running.len() + waiting.len());
        for id in running.into_iter().chain(waiting) {
            let r = self.reqs.remove(&id).expect("scheduler holds unknown request");
            if self.kv.has_request(id.0) {
                self.kv.free_request(id.0);
            }
            // Only admitted (Running) requests hold an adapter ref;
            // Waiting never acquired and Preempted already released.
            if let (State::Running, ModelTarget::Adapter(aid)) = (r.state, r.target) {
                self.residency.release(aid);
            }
            self.metrics.requests_received -= 1;
            self.metrics.prompt_tokens -= r.prompt.len() as u64;
            let watched = self.watched.remove(&id);
            out.push(EvacuatedRequest {
                id,
                target: r.target,
                prompt: r.prompt,
                params: r.params,
                cache_salt: r.hash_ctx.cache_salt,
                arrival: r.timeline.arrival,
                preemptions: r.preemptions,
                watched,
            });
        }
        let gone: FxHashSet<RequestId> = out.iter().map(|e| e.id).collect();
        self.events.retain(|ev| !gone.contains(&ev.id()));
        self.refresh_gauges();
        out
    }

    /// Wipe this engine's device state after a failure — the second half
    /// of failover, run once [`Self::evacuate_requests`] emptied the
    /// queues. Releases every session lease (returning the orphaned keys
    /// so the serving layer repairs the sessions), evicts every resident
    /// adapter, and purges the cached hashes, so the replica's routable
    /// cache reads exactly empty (a later restore starts cold, and the
    /// router stops scoring blocks that no longer exist).
    pub fn fail_storage(&mut self) -> Vec<u64> {
        let orphaned = self.kv.release_all_leases();
        self.residency.evict_all_idle(&mut self.kv);
        self.kv.purge_cached();
        self.refresh_gauges();
        orphaned
    }

    /// Resubmit an evacuated request on this engine under its ORIGINAL id
    /// (failover requeue; the id spaces are disjoint by construction, so
    /// a foreign id can never collide with this replica's own). `chain`
    /// may pre-seed the router's hash chain like
    /// [`Self::submit_prehashed`]'s. The request restarts from scratch —
    /// arrival and preemption count carry over, generation does not.
    pub(crate) fn submit_evacuated(
        &mut self,
        ev: EvacuatedRequest,
        chain: ChainRef,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.reqs.contains_key(&ev.id),
            "request {:?} already lives on this replica",
            ev.id
        );
        // Keep the original arrival so the failover delay reads as queue
        // time — clamped to this engine's local clock: a busy survivor's
        // timeline can lag the fleet clock the arrival was stamped on,
        // and an arrival in the local future would yield negative queue
        // times (replicas are parallel machines with their own clocks).
        let arrival = ev.arrival.min(self.clock);
        let mut req = self.prepare_request(
            ev.id,
            ev.target,
            ev.prompt,
            ev.params,
            arrival,
            ev.cache_salt,
            chain,
        )?;
        req.preemptions = ev.preemptions;
        // Continuation priority: requeued work was already admitted once;
        // it goes ahead of traffic that arrived after it.
        self.admit_prepared(req, true);
        if ev.watched {
            self.watch(ev.id);
        }
        Ok(())
    }

    /// Drive one engine step. Returns false when nothing was schedulable
    /// (idle: caller advances the clock to the next arrival or stops).
    pub fn step(&mut self) -> bool {
        // Mature any adapter-weight transfer whose completion time has
        // passed, BEFORE packing: a load that finished during the last
        // step's elapsed time must admit this step (DESIGN.md §20).
        self.residency.settle(self.clock);
        let step =
            self.sched
                .schedule(&mut self.reqs, &mut self.kv, &mut self.residency, self.clock);
        self.metrics.engine_steps += 1;
        if step.is_empty() {
            // Nothing runnable, but an adapter-weight transfer may still
            // be in flight (every admission stalled behind it): advance
            // the clock to its completion so the stall is charged in sim
            // time and the next step can admit. This is the load-stall
            // analogue of execution advancing the clock.
            if let Some(ready_at) = self.residency.earliest_pending_ready() {
                if ready_at > self.clock {
                    self.clock = ready_at;
                    self.residency.settle(self.clock);
                    self.refresh_gauges();
                    return true;
                }
            }
            self.refresh_gauges();
            return false;
        }

        // Lifecycle: first_scheduled for newly admitted (not re-admissions
        // after preemption — queue time is measured to FIRST execution).
        for id in &step.admitted {
            let r = self.reqs.get_mut(id).unwrap();
            if r.timeline.first_scheduled.is_nan() {
                r.timeline.first_scheduled = self.clock;
                if self.watched.contains(id) {
                    self.events.push(TurnEvent::Started {
                        id: *id,
                        clock: self.clock,
                        arrival: r.timeline.arrival,
                    });
                    self.metrics.stream_events += 1;
                }
            }
        }
        self.metrics.requests_preempted += step.preempted.len() as u64;

        // Prefill accounting (hit tokens counted once, at admission).
        for id in &step.admitted {
            let r = &self.reqs[id];
            self.metrics.prefill_tokens_cached += r.num_cached_tokens as u64;
        }
        self.metrics.prefill_tokens_computed += step.num_prefill_tokens() as u64;

        // The activation-aware mask for this batch (Appendix B).
        let mask = build_batch_mask(&step.seqs, &self.reqs);

        // Execute (sim: modeled seconds; real: measured seconds).
        let result = self.exec.execute(&step, &self.reqs, &self.kv, &mask);
        self.clock += result.elapsed;

        let sampled: FxHashMap<RequestId, u32> = result.sampled.into_iter().collect();

        // Apply progress + sampling + commits.
        for s in &step.seqs {
            let block_size = self.kv.block_size();
            let r = self.reqs.get_mut(&s.id).unwrap();
            r.num_computed_tokens = s.chunk_start + s.chunk_len;

            if s.produces_token {
                let tok = sampled.get(&s.id).copied().unwrap_or(0);
                r.output_tokens.push(tok);
                if r.timeline.first_token.is_nan() {
                    r.timeline.first_token = self.clock;
                }
                if self.watched.contains(&s.id) {
                    self.events.push(TurnEvent::Token {
                        id: s.id,
                        index: (r.output_tokens.len() - 1) as u32,
                        token: tok,
                        clock: self.clock,
                    });
                    self.metrics.stream_events += 1;
                    self.metrics.stream_token_events += 1;
                }
            }

            // Extend the hash chain over any newly completed blocks and
            // commit them (shareable from now on). The chain covers
            // `num_computed / block_size` full blocks.
            let full_blocks = r.num_computed_tokens / block_size;
            if full_blocks > r.hash_chain.len() {
                let tokens = r.all_tokens();
                let mut parent = r.hash_chain.last();
                let mut delta = Vec::with_capacity(full_blocks - r.hash_chain.len());
                for idx in r.hash_chain.len()..full_blocks {
                    let h = next_block_hash(parent, &tokens, idx, block_size, &r.hash_ctx);
                    delta.push(h);
                    parent = Some(h);
                }
                r.hash_chain = r.hash_chain.extend(&delta);
            }
            // Commit only fully computed blocks: during chunked prefill a
            // pre-seeded chain can run ahead of the computed KV. The
            // prefix handle is an O(tail) walk + refcount bump — no
            // per-seq hash copy on this hot loop.
            let upto = full_blocks.min(r.hash_chain.len());
            let chain = r.hash_chain.prefix(upto);
            self.kv.commit_full_blocks(s.id.0, &chain);

            // Finish?
            let r = self.reqs.get_mut(&s.id).unwrap();
            if r.output_tokens.len() as u32 >= r.params.max_new_tokens {
                r.state = State::Finished;
                r.timeline.finished = self.clock;
                let target = r.target;
                let out = RequestOutput::from_request(r);
                self.metrics.observe_finished(&out);
                self.emit_finish(s.id, out);
                self.sched.finish(s.id);
                self.kv.free_request(s.id.0);
                // The last finisher's ref-drop turns its adapter idle
                // (warm but evictable) — residency mirrors the running set.
                if let ModelTarget::Adapter(aid) = target {
                    self.residency.release(aid);
                }
                self.reqs.remove(&s.id);
            }
        }

        self.refresh_gauges();
        true
    }

    fn refresh_gauges(&mut self) {
        self.metrics.running_requests = self.sched.num_running() as u64;
        self.metrics.waiting_requests = self.sched.num_waiting() as u64;
        self.metrics.free_blocks = self.kv.num_free_blocks() as u64;
        self.metrics.clock = self.clock;
        let ks = self.kv.stats();
        self.metrics.blocks_allocated = ks.pool.allocations;
        self.metrics.cache_hit_blocks = ks.pool.hits;
        self.metrics.cache_evictions = ks.pool.evictions;
        let rs = self.residency.stats();
        self.metrics.adapter_loads = rs.loads;
        self.metrics.adapter_evictions = rs.evictions;
        self.metrics.adapter_load_stall_steps = rs.load_stall_steps;
        self.metrics.adapter_resident_blocks = self.residency.resident_blocks() as u64;
        self.metrics.adapter_demotions = rs.demotions;
        self.metrics.adapter_promotions = rs.promotions;
        self.metrics.adapter_host_drops = rs.host_drops;
        self.metrics.adapter_prefetches = rs.prefetches;
        self.metrics.adapter_host_blocks = self.residency.host_resident_blocks() as u64;
        self.metrics.leased_blocks = self.kv.leased_blocks() as u64;
        self.metrics.lease_reclaims = ks.leases_reclaimed;
    }

    /// Run until every submitted request has finished.
    pub fn run_until_idle(&mut self) {
        while self.has_work() {
            if !self.step() {
                // Nothing schedulable but work exists => stuck (request too
                // large for capacity). Surface loudly rather than spin.
                panic!(
                    "engine stalled: {} waiting / {} running but nothing schedulable",
                    self.num_waiting(),
                    self.num_running()
                );
            }
        }
    }

    /// The single finish-emission path: every completed request flows
    /// through here. Watched requests additionally get a
    /// [`TurnEvent::Finished`] carrying a copy of the record (and their
    /// subscription ends); the ledger behind `take_finished*` always
    /// receives the canonical record, so the legacy drains are a view
    /// over the same emission, not a second bookkeeping scheme.
    fn emit_finish(&mut self, id: RequestId, out: RequestOutput) {
        if self.watched.remove(&id) {
            self.events.push(TurnEvent::Finished { id, output: out.clone() });
            self.metrics.stream_events += 1;
        }
        self.finished.push(out);
    }

    /// Subscribe to [`TurnEvent`]s for `id` (streaming turns). Call
    /// before the request is first scheduled to observe its whole
    /// lifecycle; the subscription ends at `Finished`. Unwatched requests
    /// buffer nothing.
    pub fn watch(&mut self, id: RequestId) {
        if self.watched.insert(id) {
            self.metrics.stream_subscriptions += 1;
        }
    }

    /// Cancel a subscription (streaming client went away mid-turn).
    pub fn unwatch(&mut self, id: RequestId) {
        self.watched.remove(&id);
    }

    /// Drain all events emitted for watched requests since the last
    /// drain (ownership transferred — the incremental per-step intake of
    /// a streaming server's driver loop).
    pub fn take_events(&mut self) -> Vec<TurnEvent> {
        std::mem::take(&mut self.events)
    }

    /// Pin the cached prefix of a conversation's token stream under
    /// `lease` (the session API's between-turn retention). The chain is
    /// hashed under the base context + `cache_salt` — exactly the chain a
    /// base follow-up turn presents, and (base-aligned hashing) the
    /// pre-activation chain an aLoRA turn presents. Returns blocks
    /// pinned. Best-effort: leases break oldest-first under allocation
    /// pressure, so a parked session can never wedge running work.
    pub fn lease_prefix(&mut self, lease: u64, tokens: &[u32], cache_salt: u64) -> usize {
        let ctx = self
            .registry
            .request_hash_context(
                None,
                tokens,
                self.cfg.cache.base_aligned_hashing,
                cache_salt,
            )
            .map(|(_, ctx)| ctx)
            .expect("base target always has a hash context");
        let chain = ChainRef::from_hashes(&block_hashes(
            tokens,
            self.cfg.cache.block_size as usize,
            &ctx,
        ));
        self.lease_prefix_prehashed(lease, &chain)
    }

    /// [`Self::lease_prefix`] with the chain already hashed — the session
    /// layer caches each conversation's chain and extends it O(delta) per
    /// turn, so re-leasing must not rehash the whole history. The same
    /// trust rule as [`Self::submit_prehashed`] applies: the chain must
    /// come from the engine's own `request_hash_context` salting.
    pub(crate) fn lease_prefix_prehashed(&mut self, lease: u64, chain: &ChainRef) -> usize {
        let pinned = self.kv.acquire_lease(lease, chain);
        // Refresh the gauge here, not just per step: leases change while
        // the engine is idle (between turns), and /metrics must not lag.
        self.metrics.leased_blocks = self.kv.leased_blocks() as u64;
        pinned
    }

    /// Release a prefix lease's pins (session deleted). Unknown keys are
    /// a no-op.
    pub fn release_prefix_lease(&mut self, lease: u64) {
        self.kv.release_lease(lease);
        self.metrics.leased_blocks = self.kv.leased_blocks() as u64;
    }

    /// Destination side of a cross-replica migration (DESIGN.md §18):
    /// splice a shipped chain's blocks into this replica's pool and
    /// register them under `lease`. The transfer-time charge and the
    /// migrate-vs-recompute decision live in `Cluster::migrate_lease`;
    /// this is only the storage splice. Returns blocks installed.
    pub(crate) fn install_migrated_lease(&mut self, lease: u64, chain: &ChainRef) -> usize {
        let installed = self.kv.install_migrated_lease(lease, chain);
        self.metrics.leased_blocks = self.kv.leased_blocks() as u64;
        // Freshly allocated blocks went through the pool's allocator, so
        // the blocks_allocated gauge must not lag the idle-time install.
        self.refresh_gauges();
        installed
    }

    /// The chain a lease currently pins here (None if this replica holds
    /// no such lease) — the source-side read of a migration.
    pub(crate) fn lease_chain(&self, lease: u64) -> Option<ChainRef> {
        self.kv.lease_chain(lease)
    }

    /// Every lease key this replica holds, oldest first — the enumeration
    /// a batched autoscale-down evacuation walks (DESIGN.md §19).
    pub(crate) fn lease_keys(&self) -> Vec<u64> {
        self.kv.lease_keys()
    }

    /// Drain finished request records (ownership transferred).
    pub fn take_finished(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.finished)
    }

    /// Finished-but-undrained request count (completion-drain polling).
    pub fn finished_pending(&self) -> usize {
        self.finished.len()
    }

    /// Drain only the finished outputs `pred` selects, leaving the rest
    /// queued for whoever owns them. This is the coordinator's completion
    /// intake: it consumes its conversations' outputs without re-scanning
    /// (or stealing) other traffic sharing the engine.
    pub fn take_finished_where(
        &mut self,
        mut pred: impl FnMut(&RequestOutput) -> bool,
    ) -> Vec<RequestOutput> {
        let mut taken = Vec::new();
        let mut kept = Vec::with_capacity(self.finished.len());
        for out in std::mem::take(&mut self.finished) {
            if pred(&out) {
                taken.push(out);
            } else {
                kept.push(out);
            }
        }
        self.finished = kept;
        taken
    }

    /// Test hook: sweep KV-manager + residency invariants; when idle,
    /// additionally check that no blocks leaked — every non-free block of
    /// an idle engine must be a resident adapter's weight page or a
    /// session-leased prefix block.
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        self.residency.check_invariants()?;
        let accounted = self.kv.num_free_blocks() as usize
            + self.residency.resident_blocks()
            + self.kv.leased_distinct_blocks();
        if !self.has_work() && accounted != self.kv.num_total_blocks() as usize {
            return Err(format!(
                "idle engine leaked blocks: {} free + {} adapter-resident + {} leased of {}",
                self.kv.num_free_blocks(),
                self.residency.resident_blocks(),
                self.kv.leased_distinct_blocks(),
                self.kv.num_total_blocks()
            ));
        }
        Ok(())
    }

    /// Wait for one specific request (drives steps until it completes) and
    /// return its record. Panics if the engine stalls first.
    pub fn run_to_completion(&mut self, id: RequestId) -> RequestOutput {
        loop {
            if let Some(pos) = self.finished.iter().position(|o| o.id == id) {
                return self.finished.remove(pos);
            }
            assert!(self.step(), "engine stalled waiting on {id:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::AdapterKind;
    use crate::config::presets;

    /// Trivial executor: fixed 1ms per step, argmax = position count.
    struct FixedExecutor;

    impl Executor for FixedExecutor {
        fn execute(
            &mut self,
            step: &ScheduledStep,
            _reqs: &FxHashMap<RequestId, Request>,
            _kv: &KvCacheManager,
            _mask: &BatchMask,
        ) -> StepResult {
            StepResult {
                elapsed: 0.001,
                sampled: step
                    .seqs
                    .iter()
                    .filter(|s| s.produces_token)
                    .map(|s| (s.id, 1u32))
                    .collect(),
            }
        }
    }

    fn tiny_engine() -> Engine<FixedExecutor> {
        let cfg = presets::tiny();
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        Engine::with_registry(cfg, reg, FixedExecutor)
    }

    #[test]
    fn single_request_lifecycle_and_metrics() {
        let mut e = tiny_engine();
        let id = e
            .submit(
                ModelTarget::Base,
                (0..40).collect(),
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        let out = e.run_to_completion(id);
        assert_eq!(out.output_tokens, vec![1, 1, 1, 1]);
        let t = out.timeline;
        assert!(t.queue_time() >= 0.0);
        assert!(t.prefill_time() > 0.0);
        assert!(t.decode_time() > 0.0);
        assert!((t.e2e() - (t.queue_time() + t.prefill_time() + t.decode_time())).abs() < 1e-12);
        assert_eq!(e.metrics.requests_finished, 1);
        assert_eq!(e.metrics.generated_tokens, 4);
    }

    #[test]
    fn oversized_request_rejected() {
        let mut e = tiny_engine();
        let err = e.submit(
            ModelTarget::Base,
            (0..200).collect(),
            SamplingParams { max_new_tokens: 100, ..Default::default() },
        );
        assert!(err.is_err());
    }

    #[test]
    fn alora_request_reuses_base_blocks() {
        let mut e = tiny_engine();
        // Base conversation.
        let base = e
            .submit(
                ModelTarget::Base,
                (0..64).collect(),
                SamplingParams { max_new_tokens: 16, ..Default::default() },
            )
            .unwrap();
        let base_out = e.run_to_completion(base);
        assert_eq!(base_out.num_cached_tokens, 0);

        // aLoRA 0 evaluates prompt+generation+invocation.
        let mut ev: Vec<u32> = (0..64).collect();
        ev.extend(base_out.output_tokens.iter());
        ev.extend([508, 509, 510, 511]); // adapter 0 invocation
        let ev_len = ev.len(); // 84
        let al = e
            .submit(
                ModelTarget::Adapter(crate::adapter::AdapterId(0)),
                ev,
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        let al_out = e.run_to_completion(al);
        // Base computed KV for 79 of its 80 tokens (the final sampled
        // token's KV is computed only when consumed, and the request
        // finished first) => 4 full blocks = 64 tokens are shareable, and
        // the aLoRA hits all of them (pre-activation chain == base chain).
        assert_eq!(al_out.num_cached_tokens, 64, "cross-model prefix hit");
        assert!(al_out.timeline.prefill_time() > 0.0);
        assert_eq!(al_out.prompt_len, ev_len);
    }

    #[test]
    fn lora_request_cannot_reuse() {
        let cfg = presets::tiny();
        let mut reg = AdapterRegistry::new();
        reg.register("plain-lora", AdapterKind::Lora, 8);
        let mut e = Engine::with_registry(cfg, reg, FixedExecutor);
        let base = e
            .submit(
                ModelTarget::Base,
                (0..64).collect(),
                SamplingParams { max_new_tokens: 16, ..Default::default() },
            )
            .unwrap();
        e.run_to_completion(base);
        let lora = e
            .submit(
                ModelTarget::Adapter(crate::adapter::AdapterId(0)),
                (0..64).collect(),
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        let out = e.run_to_completion(lora);
        assert_eq!(out.num_cached_tokens, 0, "LoRA must re-prefill");
    }

    #[test]
    fn adapter_paging_lifecycle_and_submit_guard() {
        let mut cfg = presets::tiny();
        cfg.cache.adapter_paging = true;
        cfg.cache.max_kv_tokens = 256; // 16-block device budget
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        let mut e = Engine::with_registry(cfg, reg, FixedExecutor);
        // tiny aLoRA (rank 32) weights = 8 blocks. A small request loads
        // them, runs, and leaves the adapter warm-but-idle at finish.
        let id = e
            .submit(
                ModelTarget::Adapter(crate::adapter::AdapterId(0)),
                (0..32).collect(),
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        e.run_to_completion(id);
        let rs = e.residency().stats();
        assert_eq!(rs.loads, 1);
        assert_eq!(rs.adapter_admissions, 1);
        assert_eq!(rs.adapter_admission_hits, 0, "cold first admission");
        assert_eq!(e.residency().resident_ids(), vec![0]);
        assert_eq!(e.memory_budget().adapter_blocks(), 8);
        e.check_invariants().unwrap();
        assert!(e
            .metrics
            .render_prometheus()
            .contains("alora_serve_adapter_resident_blocks 8"));
        // A second admission of the same adapter is a residency hit.
        let id = e
            .submit(
                ModelTarget::Adapter(crate::adapter::AdapterId(0)),
                (100..132).collect(),
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        e.run_to_completion(id);
        let rs = e.residency().stats();
        assert_eq!(rs.loads, 1, "no reload for a warm adapter");
        assert_eq!(rs.adapter_admission_hits, 1);
        // Submit guard: 150-token request = 10 KV blocks + 8 weight blocks
        // > 16-block budget — rejected up front, not stalled forever.
        let err = e.submit(
            ModelTarget::Adapter(crate::adapter::AdapterId(1)),
            (0..140).collect(),
            SamplingParams { max_new_tokens: 10, ..Default::default() },
        );
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("device budget"));
    }

    #[test]
    fn rolled_back_admission_still_counts_as_cold_load() {
        // 16-block budget. A base request pins 7 KV blocks; the adapter
        // request's gate then loads its 8 weight pages (free: 9 → 1) but
        // the 2-block KV capacity check fails → admission rolls back with
        // the adapter left resident. The retry after the base drains must
        // count a COLD admission (this request paid for the load), not a
        // warm hit from re-observing its own adapter.
        let mut cfg = presets::tiny();
        cfg.cache.adapter_paging = true;
        cfg.cache.max_kv_tokens = 256;
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        let mut e = Engine::with_registry(cfg, reg, FixedExecutor);
        let base = e
            .submit(
                ModelTarget::Base,
                (0..110).collect(),
                SamplingParams { max_new_tokens: 2, ..Default::default() },
            )
            .unwrap();
        assert!(e.step(), "base admitted and prefilled");
        let al = e
            .submit(
                ModelTarget::Adapter(crate::adapter::AdapterId(0)),
                (0..32).collect(),
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        assert!(e.step(), "base decodes; adapter admission rolls back");
        let rs = e.residency().stats();
        assert_eq!(rs.loads, 1, "gate loaded the weights");
        assert_eq!(rs.adapter_admissions, 0, "admission rolled back");
        e.run_to_completion(base);
        e.run_to_completion(al);
        let rs = e.residency().stats();
        assert_eq!(rs.adapter_admissions, 1);
        assert_eq!(rs.adapter_admission_hits, 0, "rollback retry is cold");
        assert_eq!(rs.loads, 1, "no double load");
    }

    #[test]
    fn base_aligned_flag_off_behaves_like_vanilla() {
        let mut cfg = presets::tiny();
        cfg.cache.base_aligned_hashing = false;
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        let mut e = Engine::with_registry(cfg, reg, FixedExecutor);
        let base = e
            .submit(
                ModelTarget::Base,
                (0..64).collect(),
                SamplingParams { max_new_tokens: 16, ..Default::default() },
            )
            .unwrap();
        let base_out = e.run_to_completion(base);
        let mut ev: Vec<u32> = (0..64).collect();
        ev.extend(base_out.output_tokens.iter());
        ev.extend([508, 509, 510, 511]);
        let al = e
            .submit(
                ModelTarget::Adapter(crate::adapter::AdapterId(0)),
                ev,
                SamplingParams { max_new_tokens: 4, ..Default::default() },
            )
            .unwrap();
        let out = e.run_to_completion(al);
        assert_eq!(out.num_cached_tokens, 0, "feature off: adapter isolated");
    }

    #[test]
    fn prehashed_chain_behaves_like_lazy_hashing() {
        use crate::kvcache::prefix::{block_hashes, HashContext};
        let mut e = tiny_engine();
        let p = SamplingParams { max_new_tokens: 4, ..Default::default() };
        let prompt: Vec<u32> = (0..64).collect();
        let warm = e.submit(ModelTarget::Base, prompt.clone(), p).unwrap();
        e.run_to_completion(warm);
        // A router-style pre-seeded chain must hit exactly what a lazily
        // hashed submission of the same prompt hits.
        let chain = ChainRef::from_hashes(&block_hashes(
            &prompt,
            e.cfg.cache.block_size as usize,
            &HashContext::base(),
        ));
        let pre = e
            .submit_prehashed(ModelTarget::Base, prompt.clone(), p, false, 0, chain)
            .unwrap();
        let pre_out = e.run_to_completion(pre);
        let lazy = e.submit(ModelTarget::Base, prompt, p).unwrap();
        let lazy_out = e.run_to_completion(lazy);
        assert_eq!(pre_out.num_cached_tokens, 48);
        assert_eq!(pre_out.num_cached_tokens, lazy_out.num_cached_tokens);
    }

    #[test]
    fn base_reuses_own_prefix_across_turns() {
        let mut e = tiny_engine();
        let id1 = e
            .submit(
                ModelTarget::Base,
                (0..64).collect(),
                SamplingParams { max_new_tokens: 8, ..Default::default() },
            )
            .unwrap();
        let o1 = e.run_to_completion(id1);
        let mut next: Vec<u32> = (0..64).collect();
        next.extend(o1.output_tokens.iter());
        next.push(3);
        let id2 = e
            .submit(
                ModelTarget::Base,
                next,
                SamplingParams { max_new_tokens: 8, ..Default::default() },
            )
            .unwrap();
        let o2 = e.run_to_completion(id2);
        // 64 + 8 = 72 -> 4 full blocks of first conversation reusable.
        assert_eq!(o2.num_cached_tokens, 64);
    }

    #[test]
    fn clock_monotonic_and_advance() {
        let mut e = tiny_engine();
        assert_eq!(e.clock(), 0.0);
        e.advance_clock_to(5.0);
        assert_eq!(e.clock(), 5.0);
        let id = e
            .submit(ModelTarget::Base, vec![1, 2, 3], SamplingParams::default())
            .unwrap();
        let out = e.run_to_completion(id);
        assert!(out.timeline.arrival >= 5.0);
        assert!(e.clock() > 5.0);
    }

    #[test]
    #[should_panic(expected = "monotonic")]
    fn clock_cannot_go_back() {
        let mut e = tiny_engine();
        e.advance_clock_to(5.0);
        e.advance_clock_to(4.0);
    }

    #[test]
    fn watched_request_emits_turn_events() {
        let mut e = tiny_engine();
        let p = SamplingParams { max_new_tokens: 4, ..Default::default() };
        let id = e.submit(ModelTarget::Base, (0..40).collect(), p).unwrap();
        e.watch(id);
        // An unwatched request sharing the engine buffers nothing.
        let other = e.submit(ModelTarget::Base, (100..140).collect(), p).unwrap();
        e.run_until_idle();
        let evs = e.take_events();
        assert!(evs.iter().all(|ev| ev.id() == id), "{evs:?}");
        assert!(matches!(evs.first(), Some(crate::request::TurnEvent::Started { .. })));
        let streamed: Vec<u32> = evs
            .iter()
            .filter_map(|ev| match ev {
                crate::request::TurnEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        let outs = e.take_finished();
        assert_eq!(outs.len(), 2);
        let ledger = outs.iter().find(|o| o.id == id).unwrap();
        // The streamed token sequence is byte-identical to the ledger's.
        assert_eq!(streamed, ledger.output_tokens);
        match evs.last().unwrap() {
            crate::request::TurnEvent::Finished { output, .. } => {
                assert_eq!(output.output_tokens, ledger.output_tokens);
                assert_eq!(output.timeline.finished, ledger.timeline.finished);
            }
            ev => panic!("last event must be Finished, got {ev:?}"),
        }
        // Started carries the TTFT clock inputs; Token clocks are
        // monotone and the first one equals the recorded first_token.
        match &evs[0] {
            crate::request::TurnEvent::Started { clock, arrival, .. } => {
                assert!(*clock >= *arrival);
                assert_eq!(*clock, ledger.timeline.first_scheduled);
            }
            _ => unreachable!(),
        }
        let token_clocks: Vec<f64> = evs
            .iter()
            .filter_map(|ev| match ev {
                crate::request::TurnEvent::Token { clock, .. } => Some(*clock),
                _ => None,
            })
            .collect();
        assert_eq!(token_clocks[0], ledger.timeline.first_token);
        assert!(token_clocks.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(e.metrics.stream_subscriptions, 1);
        assert_eq!(e.metrics.stream_token_events, 4);
        assert_eq!(e.metrics.stream_events, 6, "started + 4 tokens + finished");
        assert!(e.take_events().is_empty(), "drain transfers ownership once");
        let _ = other;
    }

    #[test]
    fn prefix_lease_pins_and_releases_with_leak_accounting() {
        let mut e = tiny_engine();
        let id = e
            .submit(
                ModelTarget::Base,
                (0..64).collect(),
                SamplingParams { max_new_tokens: 8, ..Default::default() },
            )
            .unwrap();
        let out = e.run_to_completion(id);
        let mut history: Vec<u32> = (0..64).collect();
        history.extend(&out.output_tokens);
        // 72 tokens = 4 full blocks, all committed (71 computed).
        assert_eq!(e.lease_prefix(1, &history, 0), 4);
        assert_eq!(e.leased_blocks(), 4);
        assert_eq!(e.num_leases(), 1);
        e.check_invariants().unwrap();
        // Gauges surface through Prometheus after the next step cycle.
        e.advance_clock_to(e.clock());
        let _ = e.step();
        assert!(e
            .metrics
            .render_prometheus()
            .contains("alora_serve_leased_blocks 4"));
        e.release_prefix_lease(1);
        assert_eq!(e.leased_blocks(), 0);
        e.check_invariants().unwrap();
    }

    #[test]
    fn evacuate_and_fail_storage_empty_the_engine() {
        let mut e = tiny_engine();
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        // One finished (its output must survive the failure), one
        // running, one waiting behind a full batch.
        let done = e.submit(ModelTarget::Base, (0..64).collect(), p).unwrap();
        e.run_to_completion(done);
        let hist: Vec<u32> = (0..64).collect();
        assert!(e.lease_prefix(7, &hist, 0) > 0);
        let running = e.submit(ModelTarget::Base, (100..164).collect(), p).unwrap();
        assert!(e.step(), "prefill the running request");
        let waiting = e
            .submit(ModelTarget::Base, (200..264).collect(), p)
            .unwrap();
        e.watch(running);
        let received_before = e.metrics.requests_received;

        let evs = e.evacuate_requests();
        assert_eq!(
            evs.iter().map(|x| x.id).collect::<Vec<_>>(),
            vec![running, waiting],
            "running (admission order) then waiting"
        );
        assert!(evs[0].watched && !evs[1].watched);
        assert_eq!(evs[0].prompt, (100..164).collect::<Vec<u32>>());
        assert!(!e.has_work());
        assert_eq!(e.metrics.requests_received, received_before - 2);
        let orphaned = e.fail_storage();
        assert_eq!(orphaned, vec![7]);
        assert_eq!(e.leased_blocks(), 0);
        assert_eq!(e.routing_summary().committed_blocks(), 0, "cache wiped");
        assert_eq!(e.num_free_blocks(), e.num_total_blocks());
        e.check_invariants().unwrap();
        // The finished ledger survived: completion state is serving-layer
        // state, not device memory.
        assert!(e.take_finished().iter().any(|o| o.id == done));

        // Requeue on a fresh "survivor": same id, carried arrival, fresh
        // run to completion.
        let cfg = presets::tiny();
        let reg = AdapterRegistry::tiny_default(3, 512, 4);
        let mut survivor = Engine::with_registry(cfg, reg, FixedExecutor);
        survivor.set_id_namespace(1, 2); // disjoint namespace: issues odd ids
        let arrival = evs[0].arrival;
        survivor.advance_clock_to(arrival); // fleet time at failover
        for ev in evs {
            survivor.submit_evacuated(ev, ChainRef::empty()).unwrap();
        }
        let out = survivor.run_to_completion(running);
        assert_eq!(out.id, running);
        assert_eq!(out.timeline.arrival, arrival, "queue-time stays honest");
        assert_eq!(out.output_tokens.len(), 8);
        let evs2 = survivor.take_events();
        assert!(
            evs2.iter().all(|ev| ev.id() == running),
            "watch re-subscribed on the survivor"
        );
        survivor.run_until_idle();
        assert!(survivor
            .take_finished()
            .iter()
            .any(|o| o.id == waiting));
        survivor.check_invariants().unwrap();
        // A duplicate requeue of a live id is refused.
        let dup = EvacuatedRequest {
            id: waiting,
            target: ModelTarget::Base,
            prompt: vec![1; 8],
            params: SamplingParams { max_new_tokens: 1, ..Default::default() },
            cache_salt: 0,
            arrival: 0.0,
            preemptions: 0,
            watched: false,
        };
        let mut busy = tiny_engine();
        busy.submit_evacuated(dup.clone(), ChainRef::empty()).unwrap();
        assert!(busy.submit_evacuated(dup, ChainRef::empty()).is_err());
    }

    #[test]
    fn prometheus_endpoint_renders() {
        let mut e = tiny_engine();
        let id = e
            .submit(ModelTarget::Base, (0..32).collect(), SamplingParams::default())
            .unwrap();
        e.run_to_completion(id);
        let text = e.metrics.render_prometheus();
        assert!(text.contains("alora_serve_requests_finished_total 1"));
    }
}
