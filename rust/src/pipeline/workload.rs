//! Workload synthesis: prompts, invocation sequences, arrival processes,
//! and registry construction for aLoRA-vs-LoRA comparisons.
//!
//! Paper §4.1: "Prompts were generated randomly to fulfill the desired
//! number of tokens"; "adapter ranks were 8 and 32 for LoRAs and aLoRAs";
//! activation sequences are appended "in both aLoRA and LoRA trials for
//! fairness".

use crate::adapter::{AdapterKind, AdapterRegistry};
#[cfg(test)]
use crate::adapter::AdapterId;
use crate::config::EngineConfig;
use crate::util::rng::Rng;

/// Vocab positions reserved at the top for invocation sequences.
pub const RESERVED_TOP: u32 = 64;
pub const INVOCATION_LEN: u32 = 4;

/// Deterministic invocation sequence for adapter index `idx` — identical
/// scheme to python/compile/configs.py (`vocab - (idx+1)·len .. `).
pub fn invocation_for(vocab: u32, idx: u32) -> Vec<u32> {
    let base = vocab - (idx + 1) * INVOCATION_LEN;
    (base..base + INVOCATION_LEN).collect()
}

/// Build a registry of `n` adapters, all aLoRA (ours) or all standard LoRA
/// (the paper's baseline). Both variants use the same invocation-token
/// ranges so prompts are identical across trials.
pub fn build_registry(n: u32, vocab: u32, alora: bool) -> AdapterRegistry {
    let mut reg = AdapterRegistry::new();
    for idx in 0..n {
        if alora {
            reg.register(
                format!("alora-{idx}"),
                AdapterKind::ALora { invocation_tokens: invocation_for(vocab, idx) },
                32,
            );
        } else {
            reg.register(format!("lora-{idx}"), AdapterKind::Lora, 8);
        }
    }
    reg
}

/// Random prompt of `len` tokens, avoiding the reserved invocation range.
pub fn prompt(rng: &mut Rng, len: usize, vocab: u32) -> Vec<u32> {
    rng.tokens(len, vocab, RESERVED_TOP)
}

/// Paper §4.2 batch-size rule: fill the KV cache given the maximum total
/// sequence length across the trial set (prompt + generation + eval +
/// separators), but never exceed the scheduler's max_num_seqs.
pub fn batch_size_for(cfg: &EngineConfig, max_total_len: usize) -> usize {
    let by_kv = (cfg.cache.max_kv_tokens as usize / max_total_len.max(1)).max(1);
    by_kv.min(cfg.scheduler.max_num_seqs as usize)
}

/// Poisson arrival times: cumulative exponential inter-arrivals at rate
/// `lambda` (req/s), `n` arrivals.
pub fn poisson_arrivals(rng: &mut Rng, n: usize, lambda: f64) -> Vec<f64> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(lambda);
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn invocation_matches_python_scheme() {
        assert_eq!(invocation_for(512, 0), vec![508, 509, 510, 511]);
        assert_eq!(invocation_for(512, 2), vec![500, 501, 502, 503]);
    }

    #[test]
    fn registry_variants() {
        let a = build_registry(3, 512, true);
        assert!(a.get(AdapterId(1)).unwrap().is_alora());
        assert_eq!(a.get(AdapterId(1)).unwrap().rank, 32);
        let l = build_registry(3, 512, false);
        assert!(!l.get(AdapterId(1)).unwrap().is_alora());
        assert_eq!(l.get(AdapterId(1)).unwrap().rank, 8);
    }

    #[test]
    fn prompts_avoid_reserved_range() {
        let mut rng = Rng::new(5);
        let p = prompt(&mut rng, 1000, 512);
        assert!(p.iter().all(|&t| t < 512 - RESERVED_TOP));
    }

    #[test]
    fn batch_size_rule() {
        let cfg = presets::granite_8b();
        // 351104 KV tokens / 65536+276 max len ≈ 5
        let b = batch_size_for(&cfg, 65536 + 276);
        assert_eq!(b, 5);
        // short sequences capped by max_num_seqs
        let b = batch_size_for(&cfg, 512);
        assert_eq!(b, cfg.scheduler.max_num_seqs as usize);
    }

    #[test]
    fn poisson_arrivals_monotone_with_mean_spacing() {
        let mut rng = Rng::new(9);
        let xs = poisson_arrivals(&mut rng, 2000, 4.0);
        assert!(xs.windows(2).all(|w| w[1] >= w[0]));
        let mean_gap = xs.last().unwrap() / 2000.0;
        assert!((mean_gap - 0.25).abs() < 0.02, "gap={mean_gap}");
    }
}
