//! Workload traces: record, save, load and replay request streams.
//!
//! The paper evaluates on synthetic workloads; production serving teams
//! replay captured traces. This module gives the engine that capability:
//! a trace is a JSON array of timed requests, replayable against any
//! executor with the same virtual-time semantics as the Poisson driver.
//!
//! Traces are coordinator-aware (DESIGN.md §6.4): an entry may carry a
//! `conversation` id, a `stage` name and `parents` links. Entries sharing
//! a conversation id form one multi-stage [`StageGraph`] — a linked
//! entry's `prompt` holds only its literal *suffix* (e.g. invocation
//! tokens); replay composes the full prompt from its parents' streams and
//! submits the stage when they finish, exactly like the live coordinator.
//! Flat entries (no conversation id) replay as single-stage conversations
//! at their recorded arrival times, so pre-existing traces are unchanged.
//! `synthesize` builds paper-shaped flat traces; `synthesize_conversations`
//! builds parent-linked multi-stage ones.

use std::path::Path;

use crate::adapter::AdapterId;
use crate::coordinator::{Coordinator, CoordinatorResult, Part, StageGraph, StageId, StageSpec};
use crate::engine::{Engine, Executor};
use crate::request::{ModelTarget, RequestOutput};
use crate::util::fxmap::FxHashMap;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::workload;

#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    /// Arrival time in seconds from trace start. For parent-linked entries
    /// this orders the entry within its conversation (replay drives it by
    /// parent completion, not by the clock).
    pub at: f64,
    /// None = base model, Some(i) = adapter i.
    pub adapter: Option<u32>,
    /// Literal prompt (flat entries / roots) or literal suffix appended
    /// after the composed parent streams (linked entries).
    pub prompt: Vec<u32>,
    pub max_new_tokens: u32,
    /// Entries sharing a conversation id form one stage graph.
    pub conversation: Option<u64>,
    /// Stage name within the conversation (parents reference it).
    pub stage: Option<String>,
    /// Parent stage names within the same conversation. The first parent
    /// is primary: the stage's prompt = primary's prompt + primary's
    /// output + other parents' outputs + `prompt` (suffix).
    pub parents: Vec<String>,
}

impl TraceEntry {
    /// A flat (single-stage) entry — the pre-coordinator trace shape.
    pub fn flat(at: f64, adapter: Option<u32>, prompt: Vec<u32>, max_new_tokens: u32) -> Self {
        TraceEntry {
            at,
            adapter,
            prompt,
            max_new_tokens,
            conversation: None,
            stage: None,
            parents: Vec::new(),
        }
    }
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
}

impl Trace {
    /// Entries must be sorted by arrival; enforced on load/build. The sort
    /// is stable, so same-time entries keep their order — parent-linked
    /// stages stay after their parents.
    pub fn new(mut entries: Vec<TraceEntry>) -> Self {
        entries.sort_by(|a, b| a.at.partial_cmp(&b.at).expect("NaN arrival"));
        Trace { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Paper-shaped synthetic trace: Poisson arrivals of base requests,
    /// each followed (after `gap` seconds) by an adapter evaluation over
    /// the same prompt + invocation tokens. A stand-in for the production
    /// multi-turn traces we don't have (DESIGN.md §7).
    pub fn synthesize(
        n: usize,
        lambda: f64,
        prompt_len: usize,
        base_gen: u32,
        eval_gen: u32,
        vocab: u32,
        seed: u64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let arrivals = workload::poisson_arrivals(&mut rng, n, lambda);
        let mut entries = Vec::with_capacity(n * 2);
        for (i, &at) in arrivals.iter().enumerate() {
            let prompt = workload::prompt(&mut rng, prompt_len, vocab);
            entries.push(TraceEntry::flat(at, None, prompt.clone(), base_gen));
            // Adapter evaluation scheduled shortly after (replay drives it
            // by arrival time, not by completion — a recorded trace has
            // concrete timestamps).
            let adapter = (i % 3) as u32;
            let mut ev = prompt;
            ev.extend(workload::invocation_for(vocab, adapter));
            entries.push(TraceEntry::flat(at + 0.5, Some(adapter), ev, eval_gen));
        }
        Trace::new(entries)
    }

    /// Parent-linked synthetic trace: `n` conversations arriving Poisson,
    /// each a base1 → N adapter evals → consolidated base2 graph (the
    /// §4.4.1 shape). Replay chains stages by completion.
    #[allow(clippy::too_many_arguments)]
    pub fn synthesize_conversations(
        n: usize,
        lambda: f64,
        prompt_len: usize,
        base_gen: u32,
        eval_gen: u32,
        base2_gen: u32,
        n_adapters: u32,
        vocab: u32,
        seed: u64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let arrivals = workload::poisson_arrivals(&mut rng, n, lambda);
        let mut entries = Vec::new();
        for (i, &at) in arrivals.iter().enumerate() {
            let cid = i as u64;
            entries.push(TraceEntry {
                at,
                adapter: None,
                prompt: workload::prompt(&mut rng, prompt_len, vocab),
                max_new_tokens: base_gen,
                conversation: Some(cid),
                stage: Some("base1".into()),
                parents: Vec::new(),
            });
            let mut base2_parents = vec!["base1".to_string()];
            for a in 0..n_adapters {
                entries.push(TraceEntry {
                    at,
                    adapter: Some(a),
                    prompt: workload::invocation_for(vocab, a),
                    max_new_tokens: eval_gen,
                    conversation: Some(cid),
                    stage: Some(format!("eval-{a}")),
                    parents: vec!["base1".into()],
                });
                base2_parents.push(format!("eval-{a}"));
            }
            entries.push(TraceEntry {
                at,
                adapter: None,
                prompt: Vec::new(),
                max_new_tokens: base2_gen,
                conversation: Some(cid),
                stage: Some("base2".into()),
                parents: base2_parents,
            });
        }
        Trace::new(entries)
    }

    // -- JSON round-trip -----------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    let mut pairs = vec![
                        ("at", Json::num(e.at)),
                        (
                            "adapter",
                            match e.adapter {
                                None => Json::Null,
                                Some(a) => Json::num(a as f64),
                            },
                        ),
                        (
                            "prompt",
                            Json::Arr(e.prompt.iter().map(|&t| Json::num(t as f64)).collect()),
                        ),
                        ("max_new_tokens", Json::num(e.max_new_tokens as f64)),
                    ];
                    if let Some(cid) = e.conversation {
                        pairs.push(("conversation", Json::num(cid as f64)));
                    }
                    if let Some(stage) = &e.stage {
                        pairs.push(("stage", Json::str(stage.clone())));
                    }
                    if !e.parents.is_empty() {
                        pairs.push((
                            "parents",
                            Json::Arr(e.parents.iter().map(|p| Json::str(p.clone())).collect()),
                        ));
                    }
                    Json::obj(pairs)
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Trace> {
        let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("trace must be an array"))?;
        let entries = arr
            .iter()
            .map(|e| {
                let parents = match e.get("parents") {
                    None | Some(Json::Null) => Vec::new(),
                    Some(v) => v
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("`parents` must be an array"))?
                        .iter()
                        .map(|p| {
                            p.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| anyhow::anyhow!("`parents` entries must be names"))
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?,
                };
                Ok(TraceEntry {
                    at: e
                        .get("at")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow::anyhow!("entry missing `at`"))?,
                    adapter: match e.get("adapter") {
                        None | Some(Json::Null) => None,
                        Some(v) => Some(
                            v.as_u64()
                                .ok_or_else(|| anyhow::anyhow!("bad `adapter`"))?
                                as u32,
                        ),
                    },
                    prompt: e
                        .get("prompt")
                        .and_then(Json::u32_vec)
                        .ok_or_else(|| anyhow::anyhow!("entry missing `prompt`"))?,
                    max_new_tokens: e
                        .get("max_new_tokens")
                        .and_then(Json::as_u64)
                        .unwrap_or(16) as u32,
                    conversation: e.get("conversation").and_then(Json::as_u64),
                    stage: e.get("stage").and_then(Json::as_str).map(str::to_string),
                    parents,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Trace::new(entries))
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Trace> {
        Trace::from_json(&Json::parse_file(path)?)
    }
}

/// Lower a trace to per-conversation stage graphs + arrival times (the
/// coordinator's input). Flat entries become single-stage conversations;
/// a linked conversation arrives at its first entry's timestamp.
fn conversation_graphs(trace: &Trace) -> anyhow::Result<(Vec<StageGraph>, Vec<f64>)> {
    let mut graphs: Vec<StageGraph> = Vec::new();
    let mut arrivals: Vec<f64> = Vec::new();
    // conversation id -> graph index (hashed: production traces can carry
    // 100k+ conversations, a Vec scan here would be quadratic)
    let mut conv_index: FxHashMap<u64, usize> = FxHashMap::default();
    // per-graph resolved stage names (stages per conversation stay small)
    let mut names: Vec<Vec<(String, StageId)>> = Vec::new();
    for (idx, e) in trace.entries.iter().enumerate() {
        let target = match e.adapter {
            None => ModelTarget::Base,
            Some(a) => ModelTarget::Adapter(AdapterId(a)),
        };
        match e.conversation {
            None => {
                anyhow::ensure!(
                    e.parents.is_empty(),
                    "entry {idx}: parent links require a conversation id"
                );
                let mut g = StageGraph::new();
                g.add(StageSpec {
                    name: e.stage.clone().unwrap_or_else(|| "request".to_string()),
                    target,
                    gen_len: e.max_new_tokens,
                    parts: vec![Part::Tokens(e.prompt.clone())],
                    after: Vec::new(),
                    priority: false,
                })
                .map_err(|err| anyhow::anyhow!("entry {idx}: {err}"))?;
                graphs.push(g);
                arrivals.push(e.at);
                names.push(Vec::new());
            }
            Some(cid) => {
                let gi = match conv_index.get(&cid) {
                    Some(gi) => *gi,
                    None => {
                        graphs.push(StageGraph::new());
                        arrivals.push(e.at);
                        names.push(Vec::new());
                        let gi = graphs.len() - 1;
                        conv_index.insert(cid, gi);
                        gi
                    }
                };
                let stage_name = e
                    .stage
                    .clone()
                    .unwrap_or_else(|| format!("s{}", graphs[gi].len()));
                // Parent links resolve by name; a silent first-match on a
                // duplicate would wire the wrong DAG edge (the JSON spec
                // path rejects duplicates the same way).
                anyhow::ensure!(
                    names[gi].iter().all(|(n, _)| n != &stage_name),
                    "entry {idx}: duplicate stage name `{stage_name}` in conversation {cid}"
                );
                let mut parts: Vec<Part> = Vec::new();
                for (k, pname) in e.parents.iter().enumerate() {
                    let pid = names[gi]
                        .iter()
                        .find(|(n, _)| n == pname)
                        .map(|(_, id)| *id)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "entry {idx}: parent `{pname}` not defined earlier in \
                                 conversation {cid}"
                            )
                        })?;
                    if k == 0 {
                        parts.push(Part::PromptOf(pid));
                    }
                    parts.push(Part::OutputOf(pid));
                }
                if !e.prompt.is_empty() || parts.is_empty() {
                    parts.push(Part::Tokens(e.prompt.clone()));
                }
                let id = graphs[gi]
                    .add(StageSpec {
                        name: stage_name.clone(),
                        target,
                        gen_len: e.max_new_tokens,
                        parts,
                        after: Vec::new(),
                        priority: false,
                    })
                    .map_err(|err| anyhow::anyhow!("entry {idx}: {err}"))?;
                names[gi].push((stage_name, id));
            }
        }
    }
    Ok((graphs, arrivals))
}

/// Replay a trace against an engine in virtual time via the coordinator.
/// Returns outputs in completion order (the legacy flat API).
pub fn replay<E: Executor>(engine: &mut Engine<E>, trace: &Trace) -> Vec<RequestOutput> {
    replay_stages(engine, trace)
        .expect("trace replay")
        .outputs
        .into_iter()
        .map(|s| s.output)
        .collect()
}

/// Coordinator-aware replay: per-stage outputs and latencies.
pub fn replay_stages<E: Executor>(
    engine: &mut Engine<E>,
    trace: &Trace,
) -> anyhow::Result<CoordinatorResult> {
    let (graphs, arrivals) = conversation_graphs(trace)?;
    Coordinator::run_event(engine, graphs, &arrivals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::make_engine;

    #[test]
    fn json_roundtrip() {
        let t = Trace::synthesize(5, 2.0, 64, 16, 8, 49_155, 7);
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn json_roundtrip_with_parent_links() {
        let t = Trace::synthesize_conversations(3, 2.0, 64, 16, 8, 16, 2, 49_155, 7);
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(t, back);
        // 3 conversations × (base1 + 2 evals + base2)
        assert_eq!(t.len(), 12);
        assert!(t.entries.iter().any(|e| !e.parents.is_empty()));
    }

    #[test]
    fn save_load_roundtrip() {
        let t = Trace::synthesize(3, 1.0, 32, 8, 4, 49_155, 9);
        let path = std::env::temp_dir().join("alora_trace_test.json");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn entries_sorted_on_construction() {
        let t = Trace::new(vec![
            TraceEntry::flat(5.0, None, vec![1], 1),
            TraceEntry::flat(1.0, None, vec![2], 1),
        ]);
        assert!(t.entries[0].at < t.entries[1].at);
    }

    #[test]
    fn replay_completes_all_and_reuses_cache() {
        let trace = Trace::synthesize(10, 4.0, 512, 32, 8, 49_155, 11);
        let mut e = make_engine("granite-8b", true, 3);
        let outs = replay(&mut e, &trace);
        assert_eq!(outs.len(), 20);
        // adapter evals over base prompts should mostly hit
        let eval_hits: Vec<f64> = outs
            .iter()
            .filter(|o| matches!(o.target, ModelTarget::Adapter(_)))
            .map(|o| o.cache_hit_rate())
            .collect();
        assert_eq!(eval_hits.len(), 10);
        let mean = eval_hits.iter().sum::<f64>() / eval_hits.len() as f64;
        assert!(mean > 0.5, "mean eval hit rate {mean}");
        e.check_invariants().unwrap();
    }

    #[test]
    fn replay_deterministic() {
        let trace = Trace::synthesize(6, 2.0, 128, 16, 8, 49_155, 13);
        let run = || {
            let mut e = make_engine("granite-8b", true, 3);
            let outs = replay(&mut e, &trace);
            (outs.len(), e.clock())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn linked_replay_chains_stages_by_completion() {
        let trace = Trace::synthesize_conversations(4, 2.0, 256, 32, 8, 16, 2, 49_155, 17);
        let mut e = make_engine("granite-8b", true, 2);
        let r = replay_stages(&mut e, &trace).unwrap();
        assert_eq!(r.outputs.len(), 16);
        assert_eq!(r.latencies_of("base1").count(), 4);
        assert_eq!(r.latencies_of("base2").count(), 4);
        // chained stages reuse the conversation's KV
        for name in ["eval-0", "eval-1", "base2"] {
            assert!(r.hit_rate_of(name) > 0.5, "{name}: {}", r.hit_rate_of(name));
        }
        e.check_invariants().unwrap();
    }

    #[test]
    fn malformed_trace_rejected() {
        let j = Json::parse(r#"[{"prompt": [1,2]}]"#).unwrap();
        assert!(Trace::from_json(&j).is_err());
        let j = Json::parse(r#"{"not": "an array"}"#).unwrap();
        assert!(Trace::from_json(&j).is_err());
        // parent link without a conversation id
        let t = Trace::new(vec![TraceEntry {
            at: 0.0,
            adapter: None,
            prompt: vec![1],
            max_new_tokens: 1,
            conversation: None,
            stage: None,
            parents: vec!["ghost".into()],
        }]);
        let mut e = make_engine("granite-8b", true, 1);
        assert!(replay_stages(&mut e, &t).is_err());
        // unknown parent within a conversation
        let t = Trace::new(vec![TraceEntry {
            at: 0.0,
            adapter: None,
            prompt: vec![1],
            max_new_tokens: 1,
            conversation: Some(0),
            stage: Some("x".into()),
            parents: vec!["ghost".into()],
        }]);
        assert!(replay_stages(&mut e, &t).is_err());
        // duplicate stage name within a conversation
        let dup = |at| TraceEntry {
            at,
            adapter: None,
            prompt: vec![1],
            max_new_tokens: 1,
            conversation: Some(0),
            stage: Some("x".into()),
            parents: Vec::new(),
        };
        let t = Trace::new(vec![dup(0.0), dup(0.1)]);
        assert!(replay_stages(&mut e, &t).is_err());
    }
}
