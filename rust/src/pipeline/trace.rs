//! Workload traces: record, save, load and replay request streams.
//!
//! The paper evaluates on synthetic workloads; production serving teams
//! replay captured traces. This module gives the engine that capability:
//! a trace is a JSON array of timed requests (arrival, target, prompt,
//! generation length), replayable against any executor with the same
//! virtual-time semantics as the Poisson driver. `synthesize` builds
//! paper-shaped traces so the two paths share tooling.

use std::path::Path;

use crate::adapter::AdapterId;
use crate::engine::{Engine, Executor};
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::workload;

#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    /// Arrival time in seconds from trace start.
    pub at: f64,
    /// None = base model, Some(i) = adapter i.
    pub adapter: Option<u32>,
    pub prompt: Vec<u32>,
    pub max_new_tokens: u32,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
}

impl Trace {
    /// Entries must be sorted by arrival; enforced on load/build.
    pub fn new(mut entries: Vec<TraceEntry>) -> Self {
        entries.sort_by(|a, b| a.at.partial_cmp(&b.at).expect("NaN arrival"));
        Trace { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Paper-shaped synthetic trace: Poisson arrivals of base requests,
    /// each followed (after `gap` seconds) by an adapter evaluation over
    /// the same prompt + invocation tokens. A stand-in for the production
    /// multi-turn traces we don't have (DESIGN.md §7).
    pub fn synthesize(
        n: usize,
        lambda: f64,
        prompt_len: usize,
        base_gen: u32,
        eval_gen: u32,
        vocab: u32,
        seed: u64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let arrivals = workload::poisson_arrivals(&mut rng, n, lambda);
        let mut entries = Vec::with_capacity(n * 2);
        for (i, &at) in arrivals.iter().enumerate() {
            let prompt = workload::prompt(&mut rng, prompt_len, vocab);
            entries.push(TraceEntry {
                at,
                adapter: None,
                prompt: prompt.clone(),
                max_new_tokens: base_gen,
            });
            // Adapter evaluation scheduled shortly after (replay drives it
            // by arrival time, not by completion — a recorded trace has
            // concrete timestamps).
            let adapter = (i % 3) as u32;
            let mut ev = prompt;
            ev.extend(workload::invocation_for(vocab, adapter));
            entries.push(TraceEntry {
                at: at + 0.5,
                adapter: Some(adapter),
                prompt: ev,
                max_new_tokens: eval_gen,
            });
        }
        Trace::new(entries)
    }

    // -- JSON round-trip -----------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("at", Json::num(e.at)),
                        (
                            "adapter",
                            match e.adapter {
                                None => Json::Null,
                                Some(a) => Json::num(a as f64),
                            },
                        ),
                        (
                            "prompt",
                            Json::Arr(e.prompt.iter().map(|&t| Json::num(t as f64)).collect()),
                        ),
                        ("max_new_tokens", Json::num(e.max_new_tokens as f64)),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Trace> {
        let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("trace must be an array"))?;
        let entries = arr
            .iter()
            .map(|e| {
                Ok(TraceEntry {
                    at: e
                        .get("at")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow::anyhow!("entry missing `at`"))?,
                    adapter: match e.get("adapter") {
                        None | Some(Json::Null) => None,
                        Some(v) => Some(
                            v.as_u64()
                                .ok_or_else(|| anyhow::anyhow!("bad `adapter`"))?
                                as u32,
                        ),
                    },
                    prompt: e
                        .get("prompt")
                        .and_then(Json::u32_vec)
                        .ok_or_else(|| anyhow::anyhow!("entry missing `prompt`"))?,
                    max_new_tokens: e
                        .get("max_new_tokens")
                        .and_then(Json::as_u64)
                        .unwrap_or(16) as u32,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Trace::new(entries))
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Trace> {
        Trace::from_json(&Json::parse_file(path)?)
    }
}

/// Replay a trace against an engine in virtual time. Returns outputs in
/// completion order.
pub fn replay<E: Executor>(engine: &mut Engine<E>, trace: &Trace) -> Vec<RequestOutput> {
    let mut outputs = Vec::with_capacity(trace.len());
    let mut next = 0usize;
    let mut submitted: Vec<RequestId> = Vec::new();
    while outputs.len() < trace.len() {
        while next < trace.entries.len() && trace.entries[next].at <= engine.clock() {
            let e = &trace.entries[next];
            next += 1;
            let target = match e.adapter {
                None => ModelTarget::Base,
                Some(a) => ModelTarget::Adapter(AdapterId(a)),
            };
            let id = engine
                .submit(
                    target,
                    e.prompt.clone(),
                    SamplingParams { max_new_tokens: e.max_new_tokens, ..Default::default() },
                )
                .expect("trace submit");
            submitted.push(id);
        }
        let progressed = engine.step();
        outputs.extend(engine.take_finished());
        if !progressed {
            if next < trace.entries.len() {
                let t = trace.entries[next].at.max(engine.clock());
                engine.advance_clock_to(t);
            } else if outputs.len() < trace.len() {
                panic!("trace replay stalled at {}/{}", outputs.len(), trace.len());
            }
        }
    }
    outputs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::make_engine;

    #[test]
    fn json_roundtrip() {
        let t = Trace::synthesize(5, 2.0, 64, 16, 8, 49_155, 7);
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn save_load_roundtrip() {
        let t = Trace::synthesize(3, 1.0, 32, 8, 4, 49_155, 9);
        let path = std::env::temp_dir().join("alora_trace_test.json");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn entries_sorted_on_construction() {
        let t = Trace::new(vec![
            TraceEntry { at: 5.0, adapter: None, prompt: vec![1], max_new_tokens: 1 },
            TraceEntry { at: 1.0, adapter: None, prompt: vec![2], max_new_tokens: 1 },
        ]);
        assert!(t.entries[0].at < t.entries[1].at);
    }

    #[test]
    fn replay_completes_all_and_reuses_cache() {
        let trace = Trace::synthesize(10, 4.0, 512, 32, 8, 49_155, 11);
        let mut e = make_engine("granite-8b", true, 3);
        let outs = replay(&mut e, &trace);
        assert_eq!(outs.len(), 20);
        // adapter evals over base prompts should mostly hit
        let eval_hits: Vec<f64> = outs
            .iter()
            .filter(|o| matches!(o.target, ModelTarget::Adapter(_)))
            .map(|o| o.cache_hit_rate())
            .collect();
        assert_eq!(eval_hits.len(), 10);
        let mean = eval_hits.iter().sum::<f64>() / eval_hits.len() as f64;
        assert!(mean > 0.5, "mean eval hit rate {mean}");
        e.check_invariants().unwrap();
    }

    #[test]
    fn replay_deterministic() {
        let trace = Trace::synthesize(6, 2.0, 128, 16, 8, 49_155, 13);
        let run = || {
            let mut e = make_engine("granite-8b", true, 3);
            let outs = replay(&mut e, &trace);
            (outs.len(), e.clock())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn malformed_trace_rejected() {
        let j = Json::parse(r#"[{"prompt": [1,2]}]"#).unwrap();
        assert!(Trace::from_json(&j).is_err());
        let j = Json::parse(r#"{"not": "an array"}"#).unwrap();
        assert!(Trace::from_json(&j).is_err());
    }
}
