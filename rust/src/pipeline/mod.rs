//! Multi-turn, multi-adapter pipeline drivers (paper §4.1), now thin
//! constructors over the stage-graph [`crate::coordinator`].
//!
//! The atomic pattern: query base model M₁ with prompt x → response y;
//! query adapter(s) A_i with (x + y + invocation) → evaluation r; then in
//! some trials feed (x + y + r…) back into M₁. The four paper shapes are
//! kept as a closed [`PipelineKind`] enum for the figure harness, but each
//! is now just a [`StageGraph`] built by [`PipelineSpec::stage_graph`] and
//! driven by the coordinator:
//!
//! - [`run_sync`] — the synchronous trials (§4.2/§4.4) via
//!   [`Coordinator::run_lockstep`]: a batch of B conversations advances
//!   one topological level at a time (all base calls, then all adapter
//!   evals, then the consolidation), matching the paper's fixed-batch
//!   methodology.
//! - [`run_poisson`] — the asynchronous trials (§4.3) via
//!   [`Coordinator::run_event`]: conversations arrive as a Poisson
//!   process; each follow-up stage is submitted the moment its parents
//!   finish, while the parents' prefix blocks are still cache-hot.
//!
//! Both run against any [`EngineDriver`] — a simulator or real engine, or
//! a whole [`crate::cluster::Cluster`] of replicas for the fleet-scaling
//! figure. Arbitrary DAGs beyond the four shapes go straight to the
//! coordinator (see `examples/multi_adapter_pipeline.rs` and
//! `POST /pipeline`).

pub mod trace;
pub mod workload;

use crate::adapter::AdapterId;
use crate::coordinator::{Coordinator, CoordinatorResult, Part, StageGraph, StageSpec};
use crate::engine::EngineDriver;
use crate::metrics::StageLatencies;
use crate::request::{ModelTarget, RequestOutput};
use crate::util::rng::Rng;

/// Which pipeline shape to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineKind {
    /// base → adapter eval (§4.2).
    BaseAdapter,
    /// adapter eval → base (Appendix C).
    AdapterBase,
    /// base → adapter → base (§4.4).
    BaseAdapterBase,
    /// base → N parallel adapters → consolidated base (§4.4.1).
    MultiAdapter,
}

/// Stage tags on finished requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Base1,
    Eval(AdapterId),
    Base2,
}

#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub kind: PipelineKind,
    pub prompt_len: usize,
    /// Base model generation length (y).
    pub base_gen: u32,
    /// Adapter evaluation length (r) — paper uses 16.
    pub eval_gen: u32,
    /// Adapters used (one for single-adapter kinds; N for MultiAdapter).
    pub adapters: Vec<AdapterId>,
    /// Generation length of the second base call (BaseAdapterBase /
    /// MultiAdapter); paper uses 16–256.
    pub base2_gen: u32,
    /// Submit conversation continuations (adapter evals, base2) with queue
    /// priority so their cached prefixes are harvested before eviction —
    /// pairs with SchedulerConfig::admission_watermark (paper §4.3 load
    /// management; see figures::ablations::watermark_sweep). Honored by
    /// the event drive only (the sync trials are fixed batches).
    pub priority_continuations: bool,
}

impl PipelineSpec {
    pub fn base_adapter(prompt_len: usize, base_gen: u32, eval_gen: u32) -> Self {
        PipelineSpec {
            kind: PipelineKind::BaseAdapter,
            prompt_len,
            base_gen,
            eval_gen,
            adapters: vec![AdapterId(0)],
            base2_gen: 16,
            priority_continuations: false,
        }
    }

    /// Worst-case total sequence length of one conversation (for the
    /// paper's batch-size rule).
    pub fn max_total_len(&self) -> usize {
        let inv = workload::INVOCATION_LEN as usize;
        let evals = match self.kind {
            PipelineKind::MultiAdapter => self.adapters.len(),
            _ => 1,
        };
        self.prompt_len
            + self.base_gen as usize
            + evals * (self.eval_gen as usize + inv)
            + self.base2_gen as usize
    }

    /// Build the stage graph for ONE conversation with literal prompt `x`
    /// (paper §4.1 composition rules), plus the legacy [`Stage`] tag of
    /// each node — `tags[stage_id.0]` labels the coordinator's outputs.
    pub fn stage_graph(&self, prompt: Vec<u32>, vocab: u32) -> (StageGraph, Vec<Stage>) {
        let inv = |aid: AdapterId| workload::invocation_for(vocab, aid.0);
        let pc = self.priority_continuations;
        let mut g = StageGraph::new();
        let mut tags = Vec::new();
        match self.kind {
            PipelineKind::BaseAdapter | PipelineKind::BaseAdapterBase | PipelineKind::MultiAdapter => {
                let b1 = g
                    .add(StageSpec {
                        name: "base1".into(),
                        target: ModelTarget::Base,
                        gen_len: self.base_gen,
                        parts: vec![Part::Tokens(prompt)],
                        after: Vec::new(),
                        priority: false,
                    })
                    .expect("base1 stage");
                tags.push(Stage::Base1);
                let eval_adapters: &[AdapterId] = match self.kind {
                    PipelineKind::MultiAdapter => &self.adapters,
                    _ => &self.adapters[..1],
                };
                let mut evals = Vec::new();
                for &aid in eval_adapters {
                    let e = g
                        .add(StageSpec {
                            name: format!("eval-{}", aid.0),
                            target: ModelTarget::Adapter(aid),
                            gen_len: self.eval_gen,
                            parts: vec![
                                Part::PromptOf(b1),
                                Part::OutputOf(b1),
                                Part::Tokens(inv(aid)),
                            ],
                            after: Vec::new(),
                            priority: pc,
                        })
                        .expect("eval stage");
                    tags.push(Stage::Eval(aid));
                    evals.push(e);
                }
                if self.kind != PipelineKind::BaseAdapter {
                    // Consolidated second base call: x + y + all evaluations.
                    let mut parts = vec![Part::PromptOf(b1), Part::OutputOf(b1)];
                    parts.extend(evals.iter().map(|&e| Part::OutputOf(e)));
                    g.add(StageSpec {
                        name: "base2".into(),
                        target: ModelTarget::Base,
                        gen_len: self.base2_gen,
                        parts,
                        after: Vec::new(),
                        priority: pc,
                    })
                    .expect("base2 stage");
                    tags.push(Stage::Base2);
                }
            }
            PipelineKind::AdapterBase => {
                // Eval first over (x + invocation); base then consumes
                // (x + r) — reuse direction adapter→base: the base call
                // harvests the adapter's pre-activation prefill of x.
                let aid = self.adapters[0];
                let mut eval_prompt = prompt.clone();
                eval_prompt.extend(inv(aid));
                let e = g
                    .add(StageSpec {
                        name: format!("eval-{}", aid.0),
                        target: ModelTarget::Adapter(aid),
                        gen_len: self.eval_gen,
                        parts: vec![Part::Tokens(eval_prompt)],
                        after: Vec::new(),
                        priority: pc,
                    })
                    .expect("eval stage");
                tags.push(Stage::Eval(aid));
                g.add(StageSpec {
                    name: "base2".into(),
                    target: ModelTarget::Base,
                    gen_len: self.base2_gen,
                    parts: vec![Part::Tokens(prompt), Part::OutputOf(e)],
                    after: Vec::new(),
                    priority: pc,
                })
                .expect("base2 stage");
                tags.push(Stage::Base2);
            }
        }
        (g, tags)
    }
}

/// All finished requests of one pipeline run, tagged by stage.
#[derive(Debug, Default)]
pub struct PipelineResult {
    pub outputs: Vec<(Stage, RequestOutput)>,
    /// Engine virtual time when the run completed.
    pub makespan: f64,
}

impl PipelineResult {
    pub fn stage_latencies(&self, want: impl Fn(Stage) -> bool) -> StageLatencies {
        let mut s = StageLatencies::default();
        for (stage, out) in &self.outputs {
            if want(*stage) {
                s.observe(out);
            }
        }
        s
    }

    /// Latencies of the adapter-evaluation stage (what most figures plot).
    pub fn eval_latencies(&self) -> StageLatencies {
        self.stage_latencies(|s| matches!(s, Stage::Eval(_)))
    }

    pub fn base2_latencies(&self) -> StageLatencies {
        self.stage_latencies(|s| s == Stage::Base2)
    }

    /// Mean prefix-cache hit rate of the eval stage.
    pub fn eval_hit_rate(&self) -> f64 {
        let evals: Vec<_> = self
            .outputs
            .iter()
            .filter(|(s, _)| matches!(s, Stage::Eval(_)))
            .collect();
        if evals.is_empty() {
            return 0.0;
        }
        evals.iter().map(|(_, o)| o.cache_hit_rate()).sum::<f64>() / evals.len() as f64
    }
}

/// Build one graph per conversation, generating prompts from `rng` in
/// submission order (prompt streams are bit-identical to the legacy
/// drivers', keeping every figure reproducible).
fn build_graphs(
    spec: &PipelineSpec,
    n: usize,
    vocab: u32,
    rng: &mut Rng,
) -> (Vec<StageGraph>, Vec<Vec<Stage>>) {
    let mut graphs = Vec::with_capacity(n);
    let mut tags = Vec::with_capacity(n);
    for _ in 0..n {
        let prompt = workload::prompt(rng, spec.prompt_len, vocab);
        let (g, t) = spec.stage_graph(prompt, vocab);
        graphs.push(g);
        tags.push(t);
    }
    (graphs, tags)
}

/// Convert a coordinator run back into the legacy tagged result.
fn to_pipeline_result(cr: CoordinatorResult, tags: &[Vec<Stage>]) -> PipelineResult {
    PipelineResult {
        outputs: cr
            .outputs
            .into_iter()
            .map(|o| (tags[o.conversation][o.stage.0], o.output))
            .collect(),
        makespan: cr.makespan,
    }
}

/// Synchronous stage-locked driver (paper §4.2 methodology): `batch`
/// conversations advance one stage at a time through the coordinator's
/// lockstep drive.
pub fn run_sync<D: EngineDriver>(
    engine: &mut D,
    spec: &PipelineSpec,
    batch: usize,
    seed: u64,
) -> PipelineResult {
    let vocab = engine.config().model.vocab_size;
    let mut rng = Rng::new(seed);
    let (graphs, tags) = build_graphs(spec, batch, vocab, &mut rng);
    let cr = Coordinator::run_lockstep(engine, graphs).expect("sync pipeline run");
    to_pipeline_result(cr, &tags)
}

/// Asynchronous Poisson driver (paper §4.3): `n` conversations arrive at
/// rate `lambda` (conversations/s); the coordinator chains each follow-up
/// stage as its parents complete.
pub fn run_poisson<D: EngineDriver>(
    engine: &mut D,
    spec: &PipelineSpec,
    n: usize,
    lambda: f64,
    seed: u64,
) -> PipelineResult {
    let vocab = engine.config().model.vocab_size;
    let mut rng = Rng::new(seed);
    let arrivals = workload::poisson_arrivals(&mut rng, n, lambda);
    let (graphs, tags) = build_graphs(spec, n, vocab, &mut rng);
    let cr = Coordinator::run_event(engine, graphs, &arrivals).expect("async pipeline run");
    to_pipeline_result(cr, &tags)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::coordinator::StageId;
    use crate::engine::Engine;
    use crate::simulator::SimExecutor;

    fn engine(alora: bool, n_adapters: u32) -> Engine<SimExecutor> {
        let mut cfg = presets::granite_8b();
        cfg.cache.base_aligned_hashing = alora;
        let reg = workload::build_registry(n_adapters, cfg.model.vocab_size, alora);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    }

    #[test]
    fn sync_base_adapter_counts_and_hits() {
        let mut e = engine(true, 1);
        let spec = PipelineSpec::base_adapter(512, 64, 16);
        let r = run_sync(&mut e, &spec, 4, 7);
        assert_eq!(r.outputs.len(), 8); // 4 base + 4 eval
        let evals = r.eval_latencies();
        assert_eq!(evals.count(), 4);
        assert!(r.eval_hit_rate() > 0.8, "hit rate {}", r.eval_hit_rate());
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn sync_lora_baseline_no_hits() {
        let mut e = engine(false, 1);
        let spec = PipelineSpec::base_adapter(512, 64, 16);
        let r = run_sync(&mut e, &spec, 4, 7);
        assert_eq!(r.eval_hit_rate(), 0.0);
    }

    #[test]
    fn sync_alora_eval_faster_than_lora() {
        let spec = PipelineSpec::base_adapter(4096, 256, 16);
        let mut ea = engine(true, 1);
        let ra = run_sync(&mut ea, &spec, 4, 7);
        let mut el = engine(false, 1);
        let rl = run_sync(&mut el, &spec, 4, 7);
        let sa = ra.eval_latencies().mean("e2e");
        let sl = rl.eval_latencies().mean("e2e");
        assert!(sl / sa > 2.0, "speedup {:.2}", sl / sa);
    }

    #[test]
    fn sync_base_adapter_base_runs_all_stages() {
        let mut e = engine(true, 1);
        let spec = PipelineSpec {
            kind: PipelineKind::BaseAdapterBase,
            prompt_len: 256,
            base_gen: 64,
            eval_gen: 16,
            adapters: vec![AdapterId(0)],
            base2_gen: 32,
            priority_continuations: false,
        };
        let r = run_sync(&mut e, &spec, 2, 3);
        assert_eq!(r.outputs.iter().filter(|(s, _)| *s == Stage::Base1).count(), 2);
        assert_eq!(r.eval_latencies().count(), 2);
        assert_eq!(r.base2_latencies().count(), 2);
        // base2 reuses the conversation prefix
        let base2_hits: Vec<f64> = r
            .outputs
            .iter()
            .filter(|(s, _)| *s == Stage::Base2)
            .map(|(_, o)| o.cache_hit_rate())
            .collect();
        assert!(base2_hits.iter().all(|&h| h > 0.5), "{base2_hits:?}");
    }

    #[test]
    fn sync_multi_adapter_five_parallel() {
        let mut e = engine(true, 5);
        let spec = PipelineSpec {
            kind: PipelineKind::MultiAdapter,
            prompt_len: 256,
            base_gen: 64,
            eval_gen: 16,
            adapters: (0..5).map(AdapterId).collect(),
            base2_gen: 16,
            priority_continuations: false,
        };
        let r = run_sync(&mut e, &spec, 2, 3);
        assert_eq!(r.eval_latencies().count(), 10); // 2 conv × 5 adapters
        assert!(r.eval_hit_rate() > 0.8);
    }

    #[test]
    fn adapter_base_reuse_direction() {
        let mut e = engine(true, 1);
        let spec = PipelineSpec {
            kind: PipelineKind::AdapterBase,
            prompt_len: 512,
            base_gen: 0, // unused: AdapterBase has no first base call
            eval_gen: 256,
            adapters: vec![AdapterId(0)],
            base2_gen: 16,
            priority_continuations: false,
        };
        let r = run_sync(&mut e, &spec, 3, 11);
        // base2 reuses the adapter's pre-activation prefill
        let hits: Vec<f64> = r
            .outputs
            .iter()
            .filter(|(s, _)| *s == Stage::Base2)
            .map(|(_, o)| o.cache_hit_rate())
            .collect();
        assert!(hits.iter().all(|&h| h > 0.5), "{hits:?}");
    }

    #[test]
    fn poisson_driver_completes_all_conversations() {
        let mut e = engine(true, 1);
        let spec = PipelineSpec::base_adapter(256, 32, 8);
        let r = run_poisson(&mut e, &spec, 20, 5.0, 13);
        assert_eq!(
            r.outputs.iter().filter(|(s, _)| matches!(s, Stage::Eval(_))).count(),
            20
        );
        assert_eq!(r.outputs.len(), 40);
        assert!(r.makespan >= 0.0);
    }

    #[test]
    fn poisson_higher_rate_more_queueing() {
        let spec = PipelineSpec::base_adapter(2048, 128, 16);
        let mut slow = engine(true, 1);
        let r_slow = run_poisson(&mut slow, &spec, 30, 0.5, 21);
        let mut fast = engine(true, 1);
        let r_fast = run_poisson(&mut fast, &spec, 30, 50.0, 21);
        let q_slow = r_slow.eval_latencies().mean("queue");
        let q_fast = r_fast.eval_latencies().mean("queue");
        assert!(q_fast >= q_slow, "queueing should not shrink with load");
    }

    #[test]
    fn poisson_deterministic() {
        let spec = PipelineSpec::base_adapter(128, 16, 8);
        let run = || {
            let mut e = engine(true, 1);
            let r = run_poisson(&mut e, &spec, 10, 2.0, 5);
            r.makespan
        };
        assert_eq!(run(), run());
    }

    /// All four legacy kinds must produce the same stage structure as the
    /// bespoke drivers did, now expressed as graphs: same node names,
    /// targets and topological order.
    #[test]
    fn legacy_kinds_map_to_expected_graph_structure() {
        let vocab = 49_155;
        let mk = |kind, n_adapters: u32| PipelineSpec {
            kind,
            prompt_len: 64,
            base_gen: 8,
            eval_gen: 4,
            adapters: (0..n_adapters).map(AdapterId).collect(),
            base2_gen: 8,
            priority_continuations: false,
        };
        let shape = |spec: &PipelineSpec| {
            let (g, tags) = spec.stage_graph(vec![1; 64], vocab);
            assert_eq!(g.len(), tags.len());
            (0..g.len())
                .map(|i| {
                    let s = g.stage(StageId(i));
                    (s.name.clone(), g.level(StageId(i)))
                })
                .collect::<Vec<_>>()
        };

        assert_eq!(
            shape(&mk(PipelineKind::BaseAdapter, 1)),
            vec![("base1".to_string(), 0), ("eval-0".to_string(), 1)]
        );
        assert_eq!(
            shape(&mk(PipelineKind::AdapterBase, 1)),
            vec![("eval-0".to_string(), 0), ("base2".to_string(), 1)]
        );
        assert_eq!(
            shape(&mk(PipelineKind::BaseAdapterBase, 1)),
            vec![
                ("base1".to_string(), 0),
                ("eval-0".to_string(), 1),
                ("base2".to_string(), 2)
            ]
        );
        assert_eq!(
            shape(&mk(PipelineKind::MultiAdapter, 3)),
            vec![
                ("base1".to_string(), 0),
                ("eval-0".to_string(), 1),
                ("eval-1".to_string(), 1),
                ("eval-2".to_string(), 1),
                ("base2".to_string(), 2)
            ]
        );
    }

    /// The graphs compose exactly the prompts the legacy drivers built:
    /// eval = x + y + invocation; consolidation = x + y + r₀..r_N.
    #[test]
    fn composed_prompts_match_legacy_composition() {
        let mut e = engine(true, 2);
        let vocab = e.cfg.model.vocab_size;
        let spec = PipelineSpec {
            kind: PipelineKind::MultiAdapter,
            prompt_len: 128,
            base_gen: 16,
            eval_gen: 8,
            adapters: vec![AdapterId(0), AdapterId(1)],
            base2_gen: 8,
            priority_continuations: false,
        };
        let r = run_sync(&mut e, &spec, 1, 5);
        let base1 = &r.outputs.iter().find(|(s, _)| *s == Stage::Base1).unwrap().1;
        let conv_len = base1.prompt_len + base1.output_tokens.len();
        for (stage, out) in &r.outputs {
            match stage {
                Stage::Eval(_) => assert_eq!(
                    out.prompt_len,
                    conv_len + workload::INVOCATION_LEN as usize
                ),
                Stage::Base2 => assert_eq!(
                    out.prompt_len,
                    conv_len + 2 * spec.eval_gen as usize
                ),
                Stage::Base1 => {}
            }
        }
    }
}
