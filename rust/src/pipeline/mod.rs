//! Multi-turn, multi-adapter pipeline drivers (paper §4.1).
//!
//! The atomic pattern: query base model M₁ with prompt x → response y;
//! query adapter(s) A_i with (x + y + invocation) → evaluation r; then in
//! some trials feed (x + y + r…) back into M₁. Drivers come in two
//! flavors:
//!
//! - [`run_sync`] — the synchronous trials (§4.2/§4.4): a batch of B
//!   conversations advances stage-by-stage (all base calls, then all
//!   adapter evals, then the second base call), matching the paper's
//!   fixed-batch methodology.
//! - [`run_poisson`] — the asynchronous trials (§4.3): conversations
//!   arrive as a Poisson process; each conversation chains its follow-up
//!   requests the moment the previous stage finishes.
//!
//! Both run against any [`Executor`] — simulator for the paper's scale,
//! RealExecutor for the end-to-end example.

pub mod trace;
pub mod workload;

use crate::adapter::AdapterId;
use crate::engine::{Engine, Executor};
use crate::metrics::StageLatencies;
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams};
use crate::util::rng::Rng;

/// Which pipeline shape to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineKind {
    /// base → adapter eval (§4.2).
    BaseAdapter,
    /// adapter eval → base (Appendix C).
    AdapterBase,
    /// base → adapter → base (§4.4).
    BaseAdapterBase,
    /// base → N parallel adapters → consolidated base (§4.4.1).
    MultiAdapter,
}

/// Stage tags on finished requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Base1,
    Eval(AdapterId),
    Base2,
}

#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub kind: PipelineKind,
    pub prompt_len: usize,
    /// Base model generation length (y).
    pub base_gen: u32,
    /// Adapter evaluation length (r) — paper uses 16.
    pub eval_gen: u32,
    /// Adapters used (one for single-adapter kinds; N for MultiAdapter).
    pub adapters: Vec<AdapterId>,
    /// Generation length of the second base call (BaseAdapterBase /
    /// MultiAdapter); paper uses 16–256.
    pub base2_gen: u32,
    /// Submit conversation continuations (adapter evals, base2) with queue
    /// priority so their cached prefixes are harvested before eviction —
    /// pairs with SchedulerConfig::admission_watermark (paper §4.3 load
    /// management; see figures::ablations::watermark_sweep).
    pub priority_continuations: bool,
}

impl PipelineSpec {
    pub fn base_adapter(prompt_len: usize, base_gen: u32, eval_gen: u32) -> Self {
        PipelineSpec {
            kind: PipelineKind::BaseAdapter,
            prompt_len,
            base_gen,
            eval_gen,
            adapters: vec![AdapterId(0)],
            base2_gen: 16, priority_continuations: false,
        }
    }

    /// Worst-case total sequence length of one conversation (for the
    /// paper's batch-size rule).
    pub fn max_total_len(&self) -> usize {
        let inv = workload::INVOCATION_LEN as usize;
        let evals = match self.kind {
            PipelineKind::MultiAdapter => self.adapters.len(),
            _ => 1,
        };
        self.prompt_len
            + self.base_gen as usize
            + evals * (self.eval_gen as usize + inv)
            + self.base2_gen as usize
    }
}

/// All finished requests of one pipeline run, tagged by stage.
#[derive(Debug, Default)]
pub struct PipelineResult {
    pub outputs: Vec<(Stage, RequestOutput)>,
    /// Engine virtual time when the run completed.
    pub makespan: f64,
}

impl PipelineResult {
    pub fn stage_latencies(&self, want: impl Fn(Stage) -> bool) -> StageLatencies {
        let mut s = StageLatencies::default();
        for (stage, out) in &self.outputs {
            if want(*stage) {
                s.observe(out);
            }
        }
        s
    }

    /// Latencies of the adapter-evaluation stage (what most figures plot).
    pub fn eval_latencies(&self) -> StageLatencies {
        self.stage_latencies(|s| matches!(s, Stage::Eval(_)))
    }

    pub fn base2_latencies(&self) -> StageLatencies {
        self.stage_latencies(|s| s == Stage::Base2)
    }

    /// Mean prefix-cache hit rate of the eval stage.
    pub fn eval_hit_rate(&self) -> f64 {
        let evals: Vec<_> = self
            .outputs
            .iter()
            .filter(|(s, _)| matches!(s, Stage::Eval(_)))
            .collect();
        if evals.is_empty() {
            return 0.0;
        }
        evals.iter().map(|(_, o)| o.cache_hit_rate()).sum::<f64>() / evals.len() as f64
    }
}

/// Conversation state for the async driver.
struct Conversation {
    prompt: Vec<u32>,
    /// Filled as stages complete.
    base_output: Vec<u32>,
    eval_outputs: Vec<(AdapterId, Vec<u32>)>,
    pending_evals: usize,
    in_flight: Vec<(RequestId, Stage)>,
}

/// Shared logic: build the eval prompt for adapter `aid` given the
/// conversation so far (x + y + invocation sequence; paper appends the
/// activation tokens in LoRA trials too, for fairness).
fn eval_prompt(vocab: u32, prompt: &[u32], base_out: &[u32], aid: AdapterId) -> Vec<u32> {
    let mut p = Vec::with_capacity(prompt.len() + base_out.len() + 4);
    p.extend_from_slice(prompt);
    p.extend_from_slice(base_out);
    p.extend(workload::invocation_for(vocab, aid.0));
    p
}

/// Consolidated second-base prompt: x + y + all evaluations.
fn base2_prompt(prompt: &[u32], base_out: &[u32], evals: &[(AdapterId, Vec<u32>)]) -> Vec<u32> {
    let mut p = Vec::with_capacity(prompt.len() + base_out.len() + 64);
    p.extend_from_slice(prompt);
    p.extend_from_slice(base_out);
    for (_, r) in evals {
        p.extend_from_slice(r);
    }
    p
}

/// Synchronous stage-locked driver (paper §4.2 methodology): `batch`
/// conversations advance one stage at a time.
pub fn run_sync<E: Executor>(
    engine: &mut Engine<E>,
    spec: &PipelineSpec,
    batch: usize,
    seed: u64,
) -> PipelineResult {
    let vocab = engine.cfg.model.vocab_size;
    let mut rng = Rng::new(seed);
    let mut result = PipelineResult::default();
    let prompts: Vec<Vec<u32>> =
        (0..batch).map(|_| workload::prompt(&mut rng, spec.prompt_len, vocab)).collect();

    // Helper: submit a wave, run to completion, return outputs in order.
    let wave = |engine: &mut Engine<E>,
                    reqs: Vec<(Stage, ModelTarget, Vec<u32>, u32)>|
     -> Vec<(Stage, RequestOutput)> {
        let ids: Vec<(RequestId, Stage)> = reqs
            .into_iter()
            .map(|(stage, target, prompt, gen)| {
                let id = engine
                    .submit(
                        target,
                        prompt,
                        SamplingParams { max_new_tokens: gen, ..Default::default() },
                    )
                    .expect("submit failed");
                (id, stage)
            })
            .collect();
        engine.run_until_idle();
        let mut outs = engine.take_finished();
        ids.iter()
            .map(|(id, stage)| {
                let pos = outs.iter().position(|o| o.id == *id).expect("missing output");
                (*stage, outs.remove(pos))
            })
            .collect()
    };

    // -- stage 1: first base call (AdapterBase skips it) -------------------
    let base_outs: Vec<Vec<u32>> = if spec.kind == PipelineKind::AdapterBase {
        vec![Vec::new(); batch]
    } else {
        let outs = wave(
            engine,
            prompts
                .iter()
                .map(|p| (Stage::Base1, ModelTarget::Base, p.clone(), spec.base_gen))
                .collect(),
        );
        let tokens = outs.iter().map(|(_, o)| o.output_tokens.clone()).collect();
        result.outputs.extend(outs);
        tokens
    };

    // -- stage 2: adapter evaluation(s) ------------------------------------
    let eval_adapters: &[AdapterId] = match spec.kind {
        PipelineKind::MultiAdapter => &spec.adapters,
        _ => &spec.adapters[..1],
    };
    let mut eval_reqs = Vec::new();
    for p_idx in 0..batch {
        for &aid in eval_adapters {
            eval_reqs.push((
                Stage::Eval(aid),
                ModelTarget::Adapter(aid),
                eval_prompt(vocab, &prompts[p_idx], &base_outs[p_idx], aid),
                spec.eval_gen,
            ));
        }
    }
    let eval_outs = wave(engine, eval_reqs);
    // Group eval outputs back per conversation (in submit order).
    let evals_per_conv = eval_adapters.len();
    let eval_tokens: Vec<Vec<(AdapterId, Vec<u32>)>> = (0..batch)
        .map(|c| {
            (0..evals_per_conv)
                .map(|e| {
                    let (stage, out) = &eval_outs[c * evals_per_conv + e];
                    let Stage::Eval(aid) = stage else { unreachable!() };
                    (*aid, out.output_tokens.clone())
                })
                .collect()
        })
        .collect();
    result.outputs.extend(eval_outs);

    // -- stage 3: second base call ------------------------------------------
    match spec.kind {
        PipelineKind::AdapterBase => {
            // base consumes (x + eval) — reuse direction adapter→base.
            let reqs = (0..batch)
                .map(|c| {
                    let mut p = prompts[c].clone();
                    p.extend(eval_tokens[c][0].1.iter());
                    (Stage::Base2, ModelTarget::Base, p, spec.base2_gen)
                })
                .collect();
            result.outputs.extend(wave(engine, reqs));
        }
        PipelineKind::BaseAdapterBase | PipelineKind::MultiAdapter => {
            let reqs = (0..batch)
                .map(|c| {
                    (
                        Stage::Base2,
                        ModelTarget::Base,
                        base2_prompt(&prompts[c], &base_outs[c], &eval_tokens[c]),
                        spec.base2_gen,
                    )
                })
                .collect();
            result.outputs.extend(wave(engine, reqs));
        }
        PipelineKind::BaseAdapter => {}
    }

    result.makespan = engine.clock();
    result
}

/// Asynchronous Poisson driver (paper §4.3): `n` conversations arrive at
/// rate `lambda` (conversations/s); each chains base → eval(s) [→ base2]
/// as stages complete.
pub fn run_poisson<E: Executor>(
    engine: &mut Engine<E>,
    spec: &PipelineSpec,
    n: usize,
    lambda: f64,
    seed: u64,
) -> PipelineResult {
    let vocab = engine.cfg.model.vocab_size;
    let mut rng = Rng::new(seed);
    let arrivals = workload::poisson_arrivals(&mut rng, n, lambda);
    let mut convs: Vec<Conversation> = (0..n)
        .map(|_| Conversation {
            prompt: workload::prompt(&mut rng, spec.prompt_len, vocab),
            base_output: Vec::new(),
            eval_outputs: Vec::new(),
            pending_evals: 0,
            in_flight: Vec::new(),
        })
        .collect();

    let mut result = PipelineResult::default();
    let mut next_arrival = 0usize;
    let with_base1 = spec.kind != PipelineKind::AdapterBase;
    let eval_adapters: Vec<AdapterId> = match spec.kind {
        PipelineKind::MultiAdapter => spec.adapters.clone(),
        _ => spec.adapters[..1].to_vec(),
    };
    let with_base2 = spec.kind != PipelineKind::BaseAdapter;
    let mut done = 0usize;

    // index: request -> conversation
    let mut owner: std::collections::HashMap<RequestId, usize> = Default::default();

    let submit_evals =
        |engine: &mut Engine<E>,
         convs: &mut [Conversation],
         owner: &mut std::collections::HashMap<RequestId, usize>,
         eval_adapters: &[AdapterId],
         spec: &PipelineSpec,
         c_idx: usize| {
            for &aid in eval_adapters {
                let p = eval_prompt(
                    engine.cfg.model.vocab_size,
                    &convs[c_idx].prompt,
                    &convs[c_idx].base_output,
                    aid,
                );
                let id = engine
                    .submit_with_priority(
                        ModelTarget::Adapter(aid),
                        p,
                        SamplingParams { max_new_tokens: spec.eval_gen, ..Default::default() },
                        spec.priority_continuations,
                    )
                    .expect("submit eval");
                convs[c_idx].in_flight.push((id, Stage::Eval(aid)));
                convs[c_idx].pending_evals += 1;
                owner.insert(id, c_idx);
            }
        };

    while done < n {
        // Feed arrivals that are due.
        while next_arrival < n && arrivals[next_arrival] <= engine.clock() {
            let c_idx = next_arrival;
            next_arrival += 1;
            if with_base1 {
                let id = engine
                    .submit(
                        ModelTarget::Base,
                        convs[c_idx].prompt.clone(),
                        SamplingParams { max_new_tokens: spec.base_gen, ..Default::default() },
                    )
                    .expect("submit base");
                convs[c_idx].in_flight.push((id, Stage::Base1));
                owner.insert(id, c_idx);
            } else {
                submit_evals(engine, &mut convs, &mut owner, &eval_adapters, spec, c_idx);
            }
        }

        let progressed = engine.step();

        // Process completions → chain next stages.
        for out in engine.take_finished() {
            let c_idx = owner[&out.id];
            let stage = convs[c_idx]
                .in_flight
                .iter()
                .find(|(id, _)| *id == out.id)
                .map(|(_, s)| *s)
                .expect("untracked request");
            convs[c_idx].in_flight.retain(|(id, _)| *id != out.id);
            match stage {
                Stage::Base1 => {
                    convs[c_idx].base_output = out.output_tokens.clone();
                    submit_evals(engine, &mut convs, &mut owner, &eval_adapters, spec, c_idx);
                }
                Stage::Eval(aid) => {
                    convs[c_idx].eval_outputs.push((aid, out.output_tokens.clone()));
                    convs[c_idx].pending_evals -= 1;
                    if convs[c_idx].pending_evals == 0 {
                        if with_base2 {
                            let p = if spec.kind == PipelineKind::AdapterBase {
                                let mut p = convs[c_idx].prompt.clone();
                                p.extend(convs[c_idx].eval_outputs[0].1.iter());
                                p
                            } else {
                                base2_prompt(
                                    &convs[c_idx].prompt,
                                    &convs[c_idx].base_output,
                                    &convs[c_idx].eval_outputs,
                                )
                            };
                            let id = engine
                                .submit_with_priority(
                                    ModelTarget::Base,
                                    p,
                                    SamplingParams {
                                        max_new_tokens: spec.base2_gen,
                                        ..Default::default()
                                    },
                                    spec.priority_continuations,
                                )
                                .expect("submit base2");
                            convs[c_idx].in_flight.push((id, Stage::Base2));
                            owner.insert(id, c_idx);
                        } else {
                            done += 1;
                        }
                    }
                }
                Stage::Base2 => {
                    done += 1;
                }
            }
            result.outputs.push((stage, out));
        }

        if !progressed {
            if next_arrival < n {
                // Idle until the next arrival.
                let t = arrivals[next_arrival].max(engine.clock());
                engine.advance_clock_to(t);
            } else if done < n && !engine.has_work() {
                panic!("async pipeline deadlock: {done}/{n} done, engine idle");
            }
        }
    }

    result.makespan = engine.clock();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::engine::Engine;
    use crate::simulator::SimExecutor;

    fn engine(alora: bool, n_adapters: u32) -> Engine<SimExecutor> {
        let mut cfg = presets::granite_8b();
        cfg.cache.base_aligned_hashing = alora;
        let reg = workload::build_registry(n_adapters, cfg.model.vocab_size, alora);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    }

    #[test]
    fn sync_base_adapter_counts_and_hits() {
        let mut e = engine(true, 1);
        let spec = PipelineSpec::base_adapter(512, 64, 16);
        let r = run_sync(&mut e, &spec, 4, 7);
        assert_eq!(r.outputs.len(), 8); // 4 base + 4 eval
        let evals = r.eval_latencies();
        assert_eq!(evals.count(), 4);
        assert!(r.eval_hit_rate() > 0.8, "hit rate {}", r.eval_hit_rate());
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn sync_lora_baseline_no_hits() {
        let mut e = engine(false, 1);
        let spec = PipelineSpec::base_adapter(512, 64, 16);
        let r = run_sync(&mut e, &spec, 4, 7);
        assert_eq!(r.eval_hit_rate(), 0.0);
    }

    #[test]
    fn sync_alora_eval_faster_than_lora() {
        let spec = PipelineSpec::base_adapter(4096, 256, 16);
        let mut ea = engine(true, 1);
        let ra = run_sync(&mut ea, &spec, 4, 7);
        let mut el = engine(false, 1);
        let rl = run_sync(&mut el, &spec, 4, 7);
        let sa = ra.eval_latencies().mean("e2e");
        let sl = rl.eval_latencies().mean("e2e");
        assert!(sl / sa > 2.0, "speedup {:.2}", sl / sa);
    }

    #[test]
    fn sync_base_adapter_base_runs_all_stages() {
        let mut e = engine(true, 1);
        let spec = PipelineSpec {
            kind: PipelineKind::BaseAdapterBase,
            prompt_len: 256,
            base_gen: 64,
            eval_gen: 16,
            adapters: vec![AdapterId(0)],
            base2_gen: 32, priority_continuations: false,
        };
        let r = run_sync(&mut e, &spec, 2, 3);
        assert_eq!(r.outputs.iter().filter(|(s, _)| *s == Stage::Base1).count(), 2);
        assert_eq!(r.eval_latencies().count(), 2);
        assert_eq!(r.base2_latencies().count(), 2);
        // base2 reuses the conversation prefix
        let base2_hits: Vec<f64> = r
            .outputs
            .iter()
            .filter(|(s, _)| *s == Stage::Base2)
            .map(|(_, o)| o.cache_hit_rate())
            .collect();
        assert!(base2_hits.iter().all(|&h| h > 0.5), "{base2_hits:?}");
    }

    #[test]
    fn sync_multi_adapter_five_parallel() {
        let mut e = engine(true, 5);
        let spec = PipelineSpec {
            kind: PipelineKind::MultiAdapter,
            prompt_len: 256,
            base_gen: 64,
            eval_gen: 16,
            adapters: (0..5).map(AdapterId).collect(),
            base2_gen: 16, priority_continuations: false,
        };
        let r = run_sync(&mut e, &spec, 2, 3);
        assert_eq!(r.eval_latencies().count(), 10); // 2 conv × 5 adapters
        assert!(r.eval_hit_rate() > 0.8);
    }

    #[test]
    fn adapter_base_reuse_direction() {
        let mut e = engine(true, 1);
        let spec = PipelineSpec {
            kind: PipelineKind::AdapterBase,
            prompt_len: 512,
            base_gen: 0, // unused
            eval_gen: 256,
            adapters: vec![AdapterId(0)],
            base2_gen: 16, priority_continuations: false,
        };
        let r = run_sync(&mut e, &spec, 3, 11);
        // base2 reuses the adapter's pre-activation prefill
        let hits: Vec<f64> = r
            .outputs
            .iter()
            .filter(|(s, _)| *s == Stage::Base2)
            .map(|(_, o)| o.cache_hit_rate())
            .collect();
        assert!(hits.iter().all(|&h| h > 0.5), "{hits:?}");
    }

    #[test]
    fn poisson_driver_completes_all_conversations() {
        let mut e = engine(true, 1);
        let spec = PipelineSpec::base_adapter(256, 32, 8);
        let r = run_poisson(&mut e, &spec, 20, 5.0, 13);
        assert_eq!(
            r.outputs.iter().filter(|(s, _)| matches!(s, Stage::Eval(_))).count(),
            20
        );
        assert_eq!(r.outputs.len(), 40);
        assert!(r.makespan >= 0.0);
    }

    #[test]
    fn poisson_higher_rate_more_queueing() {
        let spec = PipelineSpec::base_adapter(2048, 128, 16);
        let mut slow = engine(true, 1);
        let r_slow = run_poisson(&mut slow, &spec, 30, 0.5, 21);
        let mut fast = engine(true, 1);
        let r_fast = run_poisson(&mut fast, &spec, 30, 50.0, 21);
        let q_slow = r_slow.eval_latencies().mean("queue");
        let q_fast = r_fast.eval_latencies().mean("queue");
        assert!(q_fast >= q_slow, "queueing should not shrink with load");
    }

    #[test]
    fn poisson_deterministic() {
        let spec = PipelineSpec::base_adapter(128, 16, 8);
        let run = || {
            let mut e = engine(true, 1);
            let r = run_poisson(&mut e, &spec, 10, 2.0, 5);
            r.makespan
        };
        assert_eq!(run(), run());
    }
}
