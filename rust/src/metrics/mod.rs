//! Engine metrics: Table-2 definitions + Prometheus text exposition.
//!
//! The paper collects its numbers from vLLM's Prometheus endpoint; we keep
//! the same shape: counters/gauges plus per-stage latency series, and a
//! `render_prometheus()` used by the HTTP server's `/metrics`. Everything
//! is also queryable in-process (the figure harness reads the aggregates
//! directly).

use std::collections::BTreeMap;

use crate::request::RequestOutput;
use crate::util::stats::{LatencyHistogram, Samples};

/// Aggregated latency series for one request population.
#[derive(Debug, Default, Clone)]
pub struct StageLatencies {
    pub e2e: Samples,
    pub queue: Samples,
    pub prefill: Samples,
    pub decode: Samples,
    pub ttft: Samples,
    pub itl: Samples,
    /// prefill + decode (paper Appendix D "inference time").
    pub inference: Samples,
}

impl StageLatencies {
    pub fn observe(&mut self, out: &RequestOutput) {
        let t = &out.timeline;
        self.e2e.push(t.e2e());
        self.queue.push(t.queue_time());
        self.prefill.push(t.prefill_time());
        self.decode.push(t.decode_time());
        self.ttft.push(t.ttft());
        self.itl.push(out.itl());
        self.inference.push(t.prefill_time() + t.decode_time());
    }

    pub fn count(&self) -> usize {
        self.e2e.len()
    }

    /// Mean of one named stage — the figure harness's accessor.
    pub fn mean(&self, stage: &str) -> f64 {
        match stage {
            "e2e" => self.e2e.mean(),
            "queue" => self.queue.mean(),
            "prefill" => self.prefill.mean(),
            "decode" => self.decode.mean(),
            "ttft" => self.ttft.mean(),
            "itl" => self.itl.mean(),
            "inference" => self.inference.mean(),
            other => panic!("unknown stage `{other}`"),
        }
    }
}

pub const STAGES: &[&str] = &["e2e", "queue", "prefill", "decode", "ttft", "itl", "inference"];

/// Cap on distinct per-stage-name series (see [`Metrics::observe_stage`]).
pub const MAX_STAGE_SERIES: usize = 256;

/// Engine-wide metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    // counters
    pub requests_received: u64,
    pub requests_finished: u64,
    pub requests_preempted: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub engine_steps: u64,
    /// Prefill tokens actually computed (i.e. not served from cache).
    pub prefill_tokens_computed: u64,
    /// Prefill tokens served by prefix-cache hits.
    pub prefill_tokens_cached: u64,
    /// New KV blocks allocated / cache hit blocks (from the manager).
    pub blocks_allocated: u64,
    pub cache_hit_blocks: u64,
    pub cache_evictions: u64,

    // gauges (last observed)
    pub running_requests: u64,
    pub waiting_requests: u64,
    pub free_blocks: u64,
    pub clock: f64,

    // latency series
    pub all: StageLatencies,
    /// Split by model target class for the paper's per-step analysis.
    pub base: StageLatencies,
    pub adapter: StageLatencies,
    /// Per-stage-name series, fed by the coordinator as pipeline stages
    /// retire — Table-2-style breakdowns fall out of any graph shape.
    pub stage: BTreeMap<String, StageLatencies>,

    // histograms (Prometheus exposition)
    pub e2e_hist: LatencyHistogram,
    pub ttft_hist: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe_finished(&mut self, out: &RequestOutput) {
        self.requests_finished += 1;
        self.generated_tokens += out.output_tokens.len() as u64;
        self.all.observe(out);
        match out.target {
            crate::request::ModelTarget::Base => self.base.observe(out),
            crate::request::ModelTarget::Adapter(_) => self.adapter.observe(out),
        }
        self.e2e_hist.observe(out.timeline.e2e());
        self.ttft_hist.observe(out.timeline.ttft());
    }

    /// Record a finished request under a pipeline stage name (coordinator
    /// completion intake; independent of `observe_finished`, which the
    /// engine already applied). Stage names arrive from clients via
    /// `POST /pipeline`, so cardinality is bounded: past
    /// [`MAX_STAGE_SERIES`] distinct names, new ones fold into the
    /// `__other` series instead of growing memory and /metrics forever.
    pub fn observe_stage(&mut self, name: &str, out: &RequestOutput) {
        if self.stage.len() >= MAX_STAGE_SERIES && !self.stage.contains_key(name) {
            self.stage.entry("__other".to_string()).or_default().observe(out);
            return;
        }
        self.stage.entry(name.to_string()).or_default().observe(out);
    }

    /// Latency series of one stage name, if any requests retired under it.
    pub fn stage_latencies(&self, name: &str) -> Option<&StageLatencies> {
        self.stage.get(name)
    }

    /// Prefix-cache hit rate over all admitted prefill tokens.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.prefill_tokens_computed + self.prefill_tokens_cached;
        if total == 0 {
            0.0
        } else {
            self.prefill_tokens_cached as f64 / total as f64
        }
    }

    /// Throughput (Table 2): total tokens processed / total E2E time.
    pub fn throughput(&self) -> f64 {
        let tokens = self.prompt_tokens + self.generated_tokens;
        let t = self.all.e2e.sum();
        if t == 0.0 {
            0.0
        } else {
            tokens as f64 / t
        }
    }

    /// Prometheus text exposition (subset of vLLM's metric names, with the
    /// `alora_serve_` namespace).
    pub fn render_prometheus(&self) -> String {
        let mut s = String::with_capacity(4096);
        let mut counter = |name: &str, help: &str, v: f64| {
            s.push_str(&format!(
                "# HELP alora_serve_{name} {help}\n# TYPE alora_serve_{name} counter\nalora_serve_{name} {v}\n"
            ));
        };
        counter("requests_received_total", "Requests submitted", self.requests_received as f64);
        counter("requests_finished_total", "Requests completed", self.requests_finished as f64);
        counter("requests_preempted_total", "Preemptions", self.requests_preempted as f64);
        counter("prompt_tokens_total", "Prompt tokens", self.prompt_tokens as f64);
        counter("generation_tokens_total", "Generated tokens", self.generated_tokens as f64);
        counter("engine_steps_total", "Engine scheduler steps", self.engine_steps as f64);
        counter(
            "prefix_cache_hit_tokens_total",
            "Prefill tokens served from prefix cache",
            self.prefill_tokens_cached as f64,
        );
        counter(
            "prefix_cache_computed_tokens_total",
            "Prefill tokens computed",
            self.prefill_tokens_computed as f64,
        );
        counter("kv_blocks_allocated_total", "KV blocks allocated", self.blocks_allocated as f64);
        counter("kv_cache_evictions_total", "KV block evictions", self.cache_evictions as f64);

        let mut gauge = |name: &str, help: &str, v: f64| {
            s.push_str(&format!(
                "# HELP alora_serve_{name} {help}\n# TYPE alora_serve_{name} gauge\nalora_serve_{name} {v}\n"
            ));
        };
        gauge("num_requests_running", "Running requests", self.running_requests as f64);
        gauge("num_requests_waiting", "Waiting requests", self.waiting_requests as f64);
        gauge("kv_blocks_free", "Free KV blocks", self.free_blocks as f64);
        gauge("prefix_cache_hit_rate", "Token hit rate", self.cache_hit_rate());

        // Per-stage-name series (coordinator pipelines). Label values are
        // sanitized so the exposition stays `name{labels} value`, and
        // de-duplicated after sanitization — two raw names collapsing to
        // one label would emit duplicate samples, which makes Prometheus
        // reject the whole scrape.
        if !self.stage.is_empty() {
            let sanitize = |s: &str| -> String {
                s.chars()
                    .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
                    .collect()
            };
            let mut labeled: Vec<(String, &StageLatencies)> = Vec::new();
            for (name, lat) in &self.stage {
                let base = sanitize(name);
                let mut label = base.clone();
                let mut n = 2;
                while labeled.iter().any(|(l, _)| *l == label) {
                    label = format!("{base}_{n}");
                    n += 1;
                }
                labeled.push((label, lat));
            }
            for (metric, pick, ty) in [
                ("stage_requests_total", None, "counter"),
                ("stage_e2e_seconds_mean", Some("e2e"), "gauge"),
                ("stage_ttft_seconds_mean", Some("ttft"), "gauge"),
                ("stage_queue_seconds_mean", Some("queue"), "gauge"),
            ] {
                s.push_str(&format!(
                    "# HELP alora_serve_{metric} Per-pipeline-stage series\n# TYPE alora_serve_{metric} {ty}\n"
                ));
                for (label, lat) in &labeled {
                    let v = match pick {
                        None => lat.count() as f64,
                        Some(which) => lat.mean(which),
                    };
                    s.push_str(&format!(
                        "alora_serve_{metric}{{stage=\"{label}\"}} {v}\n"
                    ));
                }
            }
        }

        for (name, hist) in [("e2e_latency_seconds", &self.e2e_hist), ("ttft_seconds", &self.ttft_hist)]
        {
            s.push_str(&format!(
                "# HELP alora_serve_{name} Latency histogram\n# TYPE alora_serve_{name} histogram\n"
            ));
            for (bound, count) in hist.cumulative() {
                let le = if bound.is_infinite() { "+Inf".to_string() } else { format!("{bound}") };
                s.push_str(&format!("alora_serve_{name}_bucket{{le=\"{le}\"}} {count}\n"));
            }
            s.push_str(&format!("alora_serve_{name}_sum {}\n", hist.sum()));
            s.push_str(&format!("alora_serve_{name}_count {}\n", hist.count()));
        }
        s
    }

    /// Compact human summary used by examples and the CLI.
    pub fn summary(&mut self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        m.insert("requests_finished".into(), self.requests_finished as f64);
        m.insert("cache_hit_rate".into(), self.cache_hit_rate());
        m.insert("throughput_tok_s".into(), self.throughput());
        for stage in STAGES {
            m.insert(format!("{stage}_mean_s"), self.all.mean(stage));
        }
        let med = self.all.e2e.median();
        m.insert("e2e_median_s".into(), med);
        m.insert("e2e_p99_s".into(), self.all.e2e.p99());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{ModelTarget, RequestId, Timeline};

    fn out(arrival: f64, sched: f64, first: f64, done: f64, n_out: usize) -> RequestOutput {
        let mut t = Timeline::new(arrival);
        t.first_scheduled = sched;
        t.first_token = first;
        t.finished = done;
        RequestOutput {
            id: RequestId(0),
            target: ModelTarget::Base,
            prompt_len: 10,
            output_tokens: vec![0; n_out],
            timeline: t,
            num_cached_tokens: 5,
            preemptions: 0,
        }
    }

    #[test]
    fn observe_populates_all_series() {
        let mut m = Metrics::new();
        m.observe_finished(&out(0.0, 1.0, 2.0, 4.0, 3));
        assert_eq!(m.all.count(), 1);
        assert_eq!(m.base.count(), 1);
        assert_eq!(m.adapter.count(), 0);
        assert_eq!(m.all.mean("queue"), 1.0);
        assert_eq!(m.all.mean("prefill"), 1.0);
        assert_eq!(m.all.mean("decode"), 2.0);
        assert_eq!(m.all.mean("ttft"), 2.0);
        assert_eq!(m.all.mean("e2e"), 4.0);
        assert_eq!(m.all.mean("inference"), 3.0);
        assert_eq!(m.all.mean("itl"), 1.0);
    }

    #[test]
    fn hit_rate_and_throughput() {
        let mut m = Metrics::new();
        m.prefill_tokens_cached = 30;
        m.prefill_tokens_computed = 10;
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        m.prompt_tokens = 100;
        m.observe_finished(&out(0.0, 0.0, 1.0, 2.0, 4));
        // tokens = 100 prompt + 4 gen; e2e sum = 2.0
        assert!((m.throughput() - 52.0).abs() < 1e-9);
    }

    #[test]
    fn prometheus_exposition_wellformed() {
        let mut m = Metrics::new();
        m.requests_received = 3;
        m.observe_finished(&out(0.0, 0.1, 0.3, 0.9, 16));
        let text = m.render_prometheus();
        assert!(text.contains("alora_serve_requests_received_total 3"));
        assert!(text.contains("alora_serve_ttft_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("# TYPE alora_serve_e2e_latency_seconds histogram"));
        // every non-comment line is "name[{labels}] value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
    }

    #[test]
    fn per_stage_series_and_exposition() {
        let mut m = Metrics::new();
        m.observe_stage("draft", &out(0.0, 1.0, 2.0, 4.0, 3));
        m.observe_stage("draft", &out(0.0, 1.0, 2.0, 6.0, 3));
        m.observe_stage("eval 0?", &out(0.0, 0.5, 1.0, 2.0, 2));
        m.observe_stage("eval_0_", &out(0.0, 0.5, 1.0, 2.0, 2));
        assert_eq!(m.stage_latencies("draft").unwrap().count(), 2);
        assert_eq!(m.stage_latencies("draft").unwrap().mean("e2e"), 5.0);
        assert!(m.stage_latencies("missing").is_none());
        let text = m.render_prometheus();
        assert!(text.contains("alora_serve_stage_requests_total{stage=\"draft\"} 2"));
        // label values are sanitized to keep the exposition well-formed,
        // and post-sanitization collisions get a uniquifying suffix
        assert!(text.contains("{stage=\"eval_0_\"}"), "{text}");
        assert!(text.contains("{stage=\"eval_0__2\"}"), "{text}");
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
    }

    #[test]
    fn summary_contains_all_stages() {
        let mut m = Metrics::new();
        m.observe_finished(&out(0.0, 1.0, 2.0, 3.0, 2));
        let s = m.summary();
        for stage in STAGES {
            assert!(s.contains_key(&format!("{stage}_mean_s")), "{stage}");
        }
    }
}
