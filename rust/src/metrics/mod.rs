//! Engine metrics: Table-2 definitions + Prometheus text exposition.
//!
//! The paper collects its numbers from vLLM's Prometheus endpoint; we keep
//! the same shape: counters/gauges plus per-stage latency series, and a
//! `render_prometheus()` used by the HTTP server's `/metrics`. Everything
//! is also queryable in-process (the figure harness reads the aggregates
//! directly).

use std::collections::BTreeMap;

use crate::request::RequestOutput;
use crate::util::stats::{LatencyHistogram, Samples};

/// Aggregated latency series for one request population.
#[derive(Debug, Default, Clone)]
pub struct StageLatencies {
    pub e2e: Samples,
    pub queue: Samples,
    pub prefill: Samples,
    pub decode: Samples,
    pub ttft: Samples,
    pub itl: Samples,
    /// prefill + decode (paper Appendix D "inference time").
    pub inference: Samples,
}

impl StageLatencies {
    pub fn observe(&mut self, out: &RequestOutput) {
        let t = &out.timeline;
        self.e2e.push(t.e2e());
        self.queue.push(t.queue_time());
        self.prefill.push(t.prefill_time());
        self.decode.push(t.decode_time());
        self.ttft.push(t.ttft());
        self.itl.push(out.itl());
        self.inference.push(t.prefill_time() + t.decode_time());
    }

    pub fn count(&self) -> usize {
        self.e2e.len()
    }

    /// Merge another series (cluster aggregation across replicas).
    pub fn merge(&mut self, other: &StageLatencies) {
        self.e2e.extend_from(&other.e2e);
        self.queue.extend_from(&other.queue);
        self.prefill.extend_from(&other.prefill);
        self.decode.extend_from(&other.decode);
        self.ttft.extend_from(&other.ttft);
        self.itl.extend_from(&other.itl);
        self.inference.extend_from(&other.inference);
    }

    /// Mean of one named stage — the figure harness's accessor.
    pub fn mean(&self, stage: &str) -> f64 {
        match stage {
            "e2e" => self.e2e.mean(),
            "queue" => self.queue.mean(),
            "prefill" => self.prefill.mean(),
            "decode" => self.decode.mean(),
            "ttft" => self.ttft.mean(),
            "itl" => self.itl.mean(),
            "inference" => self.inference.mean(),
            other => panic!("unknown stage `{other}`"),
        }
    }
}

pub const STAGES: &[&str] = &["e2e", "queue", "prefill", "decode", "ttft", "itl", "inference"];

/// Cluster routing counters: how placement decisions went. Lives here so
/// the router and the Prometheus exposition agree on one definition.
#[derive(Debug, Clone, Default)]
pub struct RoutingMetrics {
    /// Requests routed per replica (index = replica).
    pub routed: Vec<u64>,
    /// PrefixAffinity placements that found a warm replica.
    pub affinity_hits: u64,
    /// PrefixAffinity placements that fell back to least-loaded (cold).
    pub affinity_fallbacks: u64,
    /// Blocks of value (cached prefix + resident adapter weights) the
    /// chosen replicas held at placement time (an upper bound on admission
    /// hits: eviction can still race the request).
    pub affinity_blocks_matched: u64,
    /// Session turns pinned to their conversation's replica (sticky
    /// placement bypassing the policy — the session API's routing).
    pub sticky_routed: u64,
    /// Replicas marked failed (`Cluster::fail_replica`).
    pub replica_failures: u64,
    /// In-flight/waiting requests requeued onto survivors at failover
    /// (fleet-unique ids preserved; callers keep their handles).
    pub requeued_requests: u64,
    /// Session prefix leases whose pins died with a failed replica (the
    /// session transparently re-prefills on its next turn).
    pub orphaned_leases: u64,
    /// Sticky turns whose conversation replica was down/draining and were
    /// re-placed through the routing policy instead (re-stick).
    pub resticks: u64,
    /// Cross-replica prefix migrations performed (transfer beat prefill).
    pub migrations: u64,
    /// KV blocks installed at destinations by those migrations.
    pub migrated_blocks: u64,
    /// Migration attempts the cost model (or pool pressure) declined —
    /// the session recomputed its prefix instead, exactly as before
    /// migration existed.
    pub migration_recompute_fallbacks: u64,
    /// Child sessions created by `POST /v1/sessions/{id}/fork`.
    pub session_forks: u64,
    /// Heartbeats the health monitor expected but did not receive
    /// (DESIGN.md §19; one per silent replica per step).
    pub heartbeat_misses: u64,
    /// `Up -> Suspected` transitions recorded by the health monitor.
    pub suspected_transitions: u64,
    /// Replicas the monitor declared `Down` after sustained misses —
    /// failures *detected*, as opposed to `replica_failures` which also
    /// counts operator-declared deaths.
    pub detected_failures: u64,
    /// Standby replicas activated by the autoscaler.
    pub scale_ups: u64,
    /// Active replicas drained back to standby by the autoscaler.
    pub scale_downs: u64,
    /// Affinity scores decayed because a gossiped summary snapshot was
    /// older than the staleness bound.
    pub stale_sketch_decays: u64,
}

impl RoutingMetrics {
    pub fn new(n_replicas: usize) -> Self {
        RoutingMetrics { routed: vec![0; n_replicas], ..Default::default() }
    }

    pub fn total_routed(&self) -> u64 {
        self.routed.iter().sum()
    }

    /// Placement imbalance: max over mean per-replica routed count.
    /// 1.0 = perfectly balanced; ~N = everything on one of N replicas.
    pub fn imbalance(&self) -> f64 {
        let total = self.total_routed();
        if self.routed.is_empty() || total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.routed.len() as f64;
        let max = *self.routed.iter().max().unwrap() as f64;
        max / mean
    }

    /// Prometheus families for the routing layer (`alora_serve_router_*`).
    pub fn render_prometheus(&self) -> String {
        let mut s = String::new();
        s.push_str(
            "# HELP alora_serve_router_requests_routed_total Requests routed per replica\n\
             # TYPE alora_serve_router_requests_routed_total counter\n",
        );
        for (i, n) in self.routed.iter().enumerate() {
            s.push_str(&format!(
                "alora_serve_router_requests_routed_total{{replica=\"{i}\"}} {n}\n"
            ));
        }
        for (name, help, v) in [
            ("affinity_hits_total", "Warm prefix placements", self.affinity_hits),
            ("affinity_fallbacks_total", "Cold-prefix least-loaded fallbacks", self.affinity_fallbacks),
            ("affinity_blocks_matched_total", "Cached blocks held by chosen replicas", self.affinity_blocks_matched),
            ("sticky_routed_total", "Session turns pinned to their conversation's replica", self.sticky_routed),
        ] {
            s.push_str(&format!(
                "# HELP alora_serve_router_{name} {help}\n# TYPE alora_serve_router_{name} counter\nalora_serve_router_{name} {v}\n"
            ));
        }
        // Failover counters live at the fleet level but are not router
        // decisions, so they keep the plain `alora_serve_` namespace
        // (names fixed by the failover surface's contract).
        for (name, help, v) in [
            ("replica_failures_total", "Replicas marked failed", self.replica_failures),
            ("requeued_requests_total", "Requests requeued onto survivors at failover", self.requeued_requests),
            ("orphaned_leases_total", "Session prefix leases lost to replica failure", self.orphaned_leases),
            ("resticks_total", "Sticky turns re-placed after their replica died or drained", self.resticks),
            ("migrations_total", "Cross-replica prefix migrations performed", self.migrations),
            ("migrated_blocks_total", "KV blocks installed at destinations by migrations", self.migrated_blocks),
            ("migration_recompute_fallbacks_total", "Migration attempts declined by the cost model", self.migration_recompute_fallbacks),
            ("session_forks_total", "Child sessions created by session fork", self.session_forks),
            ("heartbeat_misses_total", "Heartbeats expected but not received", self.heartbeat_misses),
            ("suspected_transitions_total", "Replicas transitioned Up -> Suspected", self.suspected_transitions),
            ("detected_failures_total", "Replicas declared Down by the health monitor", self.detected_failures),
            ("scale_ups_total", "Standby replicas activated by the autoscaler", self.scale_ups),
            ("scale_downs_total", "Active replicas drained to standby by the autoscaler", self.scale_downs),
            ("stale_sketch_decays_total", "Affinity scores decayed for stale gossip snapshots", self.stale_sketch_decays),
        ] {
            s.push_str(&format!(
                "# HELP alora_serve_{name} {help}\n# TYPE alora_serve_{name} counter\nalora_serve_{name} {v}\n"
            ));
        }
        s.push_str(&format!(
            "# HELP alora_serve_router_imbalance Max/mean routed per replica\n# TYPE alora_serve_router_imbalance gauge\nalora_serve_router_imbalance {}\n",
            self.imbalance()
        ));
        s
    }
}

/// Cap on distinct per-stage-name series (see [`Metrics::observe_stage`]).
pub const MAX_STAGE_SERIES: usize = 256;

/// Engine-wide metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    // counters
    pub requests_received: u64,
    pub requests_finished: u64,
    pub requests_preempted: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub engine_steps: u64,
    /// Prefill tokens actually computed (i.e. not served from cache).
    pub prefill_tokens_computed: u64,
    /// Prefill tokens served by prefix-cache hits.
    pub prefill_tokens_cached: u64,
    /// New KV blocks allocated / cache hit blocks (from the manager).
    pub blocks_allocated: u64,
    pub cache_hit_blocks: u64,
    pub cache_evictions: u64,
    /// Adapter-weight paging against the unified memory budget
    /// (`alora_serve_adapter_*`; zero when adapter_paging is off).
    pub adapter_loads: u64,
    pub adapter_evictions: u64,
    pub adapter_load_stall_steps: u64,
    /// Tiered adapter memory (DESIGN.md §20; zero without a host tier):
    /// device evictions parked host-side, host-tier reloads, host-pressure
    /// drops, and scheduler-initiated prefetch loads.
    pub adapter_demotions: u64,
    pub adapter_promotions: u64,
    pub adapter_host_drops: u64,
    pub adapter_prefetches: u64,
    /// Streaming-turn event surface (`alora_serve_stream_*`): watch
    /// subscriptions taken, events emitted, of which token events.
    pub stream_subscriptions: u64,
    pub stream_events: u64,
    pub stream_token_events: u64,
    /// Session lifecycle (`POST /v1/sessions` / `DELETE`).
    pub sessions_created: u64,
    pub sessions_closed: u64,
    /// Sessions removed by idle-TTL expiry (leases released, table slot
    /// freed) — distinct from client DELETEs.
    pub sessions_expired: u64,
    /// Session prefix leases broken under memory pressure.
    pub lease_reclaims: u64,
    /// Leases broken (oldest-first) because their tenant exceeded its
    /// per-tenant leased-block budget.
    pub tenant_lease_breaks: u64,

    // gauges (last observed)
    pub running_requests: u64,
    pub waiting_requests: u64,
    pub free_blocks: u64,
    /// Blocks currently charged to resident adapter weights.
    pub adapter_resident_blocks: u64,
    /// Block-equivalents charged to demoted adapter weights on the host
    /// tier (0 = tier disabled).
    pub adapter_host_blocks: u64,
    /// Blocks currently pinned by session prefix leases.
    pub leased_blocks: u64,
    pub clock: f64,

    // latency series
    pub all: StageLatencies,
    /// Split by model target class for the paper's per-step analysis.
    pub base: StageLatencies,
    pub adapter: StageLatencies,
    /// Per-turn series at the serving boundary: every completed session
    /// turn observed here (TTFT / ITL per turn — the numbers the v1 API
    /// makes visible). On a cluster this lives in the fleet registry.
    pub turn: StageLatencies,
    /// Per-stage-name series, fed by the coordinator as pipeline stages
    /// retire — Table-2-style breakdowns fall out of any graph shape.
    pub stage: BTreeMap<String, StageLatencies>,

    // histograms (Prometheus exposition)
    pub e2e_hist: LatencyHistogram,
    pub ttft_hist: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe_finished(&mut self, out: &RequestOutput) {
        self.requests_finished += 1;
        self.generated_tokens += out.output_tokens.len() as u64;
        self.all.observe(out);
        match out.target {
            crate::request::ModelTarget::Base => self.base.observe(out),
            crate::request::ModelTarget::Adapter(_) => self.adapter.observe(out),
        }
        self.e2e_hist.observe(out.timeline.e2e());
        self.ttft_hist.observe(out.timeline.ttft());
    }

    /// Record one completed session turn (the v1 API's per-turn TTFT /
    /// ITL series). Independent of `observe_finished`, which the engine
    /// already applied when the underlying request retired.
    pub fn observe_turn(&mut self, out: &RequestOutput) {
        self.turn.observe(out);
    }

    /// Record a finished request under a pipeline stage name (coordinator
    /// completion intake; independent of `observe_finished`, which the
    /// engine already applied). Stage names arrive from clients via
    /// `POST /pipeline`, so cardinality is bounded: past
    /// [`MAX_STAGE_SERIES`] distinct names, new ones fold into the
    /// `__other` series instead of growing memory and /metrics forever.
    pub fn observe_stage(&mut self, name: &str, out: &RequestOutput) {
        if self.stage.len() >= MAX_STAGE_SERIES && !self.stage.contains_key(name) {
            self.stage.entry("__other".to_string()).or_default().observe(out);
            return;
        }
        self.stage.entry(name.to_string()).or_default().observe(out);
    }

    /// Latency series of one stage name, if any requests retired under it.
    pub fn stage_latencies(&self, name: &str) -> Option<&StageLatencies> {
        self.stage.get(name)
    }

    /// Prefix-cache hit rate over all admitted prefill tokens.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.prefill_tokens_computed + self.prefill_tokens_cached;
        if total == 0 {
            0.0
        } else {
            self.prefill_tokens_cached as f64 / total as f64
        }
    }

    /// Throughput (Table 2): total tokens processed / total E2E time.
    pub fn throughput(&self) -> f64 {
        let tokens = self.prompt_tokens + self.generated_tokens;
        let t = self.all.e2e.sum();
        if t == 0.0 {
            0.0
        } else {
            tokens as f64 / t
        }
    }

    /// Fold another registry into this one (cluster `/metrics`
    /// aggregation): counters and gauges sum, the clock takes the max
    /// (replicas run in parallel — fleet time is the slowest replica's),
    /// latency series and histograms merge sample-exactly.
    pub fn absorb(&mut self, o: &Metrics) {
        self.absorb_scalars(o);
        self.all.merge(&o.all);
        self.base.merge(&o.base);
        self.adapter.merge(&o.adapter);
        self.turn.merge(&o.turn);
        // The same cardinality cap as `observe_stage`: merging registries
        // must not resurrect unbounded growth — names past the cap fold
        // into `__other` here too.
        for (name, lat) in &o.stage {
            if self.stage.len() >= MAX_STAGE_SERIES && !self.stage.contains_key(name) {
                self.stage.entry("__other".to_string()).or_default().merge(lat);
            } else {
                self.stage.entry(name.clone()).or_default().merge(lat);
            }
        }
    }

    /// The O(1) part of [`Metrics::absorb`]: counters, gauges, clock and
    /// the fixed-bucket histograms — everything `render_prometheus`
    /// actually exposes. The cluster's `/metrics` path uses this so a
    /// scrape never copies the raw latency sample vectors, which grow
    /// with every request served and are not rendered anyway.
    pub fn absorb_scalars(&mut self, o: &Metrics) {
        self.requests_received += o.requests_received;
        self.requests_finished += o.requests_finished;
        self.requests_preempted += o.requests_preempted;
        self.prompt_tokens += o.prompt_tokens;
        self.generated_tokens += o.generated_tokens;
        self.engine_steps += o.engine_steps;
        self.prefill_tokens_computed += o.prefill_tokens_computed;
        self.prefill_tokens_cached += o.prefill_tokens_cached;
        self.blocks_allocated += o.blocks_allocated;
        self.cache_hit_blocks += o.cache_hit_blocks;
        self.cache_evictions += o.cache_evictions;
        self.adapter_loads += o.adapter_loads;
        self.adapter_evictions += o.adapter_evictions;
        self.adapter_load_stall_steps += o.adapter_load_stall_steps;
        self.adapter_demotions += o.adapter_demotions;
        self.adapter_promotions += o.adapter_promotions;
        self.adapter_host_drops += o.adapter_host_drops;
        self.adapter_prefetches += o.adapter_prefetches;
        self.stream_subscriptions += o.stream_subscriptions;
        self.stream_events += o.stream_events;
        self.stream_token_events += o.stream_token_events;
        self.sessions_created += o.sessions_created;
        self.sessions_closed += o.sessions_closed;
        self.sessions_expired += o.sessions_expired;
        self.lease_reclaims += o.lease_reclaims;
        self.tenant_lease_breaks += o.tenant_lease_breaks;
        self.running_requests += o.running_requests;
        self.waiting_requests += o.waiting_requests;
        self.free_blocks += o.free_blocks;
        self.adapter_resident_blocks += o.adapter_resident_blocks;
        self.adapter_host_blocks += o.adapter_host_blocks;
        self.leased_blocks += o.leased_blocks;
        self.clock = self.clock.max(o.clock);
        self.e2e_hist.merge(&o.e2e_hist);
        self.ttft_hist.merge(&o.ttft_hist);
    }

    /// Per-replica labeled families for a cluster's `/metrics`: each
    /// replica's headline numbers under `alora_serve_replica_*{replica=i}`.
    /// Distinct family names (rather than re-emitting the single-engine
    /// families per replica) keep the exposition valid — every HELP/TYPE
    /// appears once, with one sample per label value.
    pub fn render_replica_families(replicas: &[&Metrics]) -> String {
        let mut s = String::new();
        let families: &[(&str, &str, &str, fn(&Metrics) -> f64)] = &[
            ("requests_finished_total", "counter", "Requests completed", |m| m.requests_finished as f64),
            ("generation_tokens_total", "counter", "Generated tokens", |m| m.generated_tokens as f64),
            ("engine_steps_total", "counter", "Scheduler steps", |m| m.engine_steps as f64),
            ("num_requests_running", "gauge", "Running requests", |m| m.running_requests as f64),
            ("num_requests_waiting", "gauge", "Waiting requests", |m| m.waiting_requests as f64),
            ("kv_blocks_free", "gauge", "Free KV blocks", |m| m.free_blocks as f64),
            ("adapter_resident_blocks", "gauge", "Resident adapter-weight blocks", |m| m.adapter_resident_blocks as f64),
            ("adapter_loads_total", "counter", "Adapter weight loads", |m| m.adapter_loads as f64),
            ("prefix_cache_hit_rate", "gauge", "Token hit rate", |m| m.cache_hit_rate()),
            ("clock_seconds", "gauge", "Virtual clock", |m| m.clock),
        ];
        for &(name, ty, help, get) in families {
            s.push_str(&format!(
                "# HELP alora_serve_replica_{name} {help}\n# TYPE alora_serve_replica_{name} {ty}\n"
            ));
            for (i, &m) in replicas.iter().enumerate() {
                s.push_str(&format!(
                    "alora_serve_replica_{name}{{replica=\"{i}\"}} {}\n",
                    get(m)
                ));
            }
        }
        s
    }

    /// Prometheus text exposition (subset of vLLM's metric names, with the
    /// `alora_serve_` namespace).
    pub fn render_prometheus(&self) -> String {
        let mut s = String::with_capacity(4096);
        let mut counter = |name: &str, help: &str, v: f64| {
            s.push_str(&format!(
                "# HELP alora_serve_{name} {help}\n# TYPE alora_serve_{name} counter\nalora_serve_{name} {v}\n"
            ));
        };
        counter("requests_received_total", "Requests submitted", self.requests_received as f64);
        counter("requests_finished_total", "Requests completed", self.requests_finished as f64);
        counter("requests_preempted_total", "Preemptions", self.requests_preempted as f64);
        counter("prompt_tokens_total", "Prompt tokens", self.prompt_tokens as f64);
        counter("generation_tokens_total", "Generated tokens", self.generated_tokens as f64);
        counter("engine_steps_total", "Engine scheduler steps", self.engine_steps as f64);
        counter(
            "prefix_cache_hit_tokens_total",
            "Prefill tokens served from prefix cache",
            self.prefill_tokens_cached as f64,
        );
        counter(
            "prefix_cache_computed_tokens_total",
            "Prefill tokens computed",
            self.prefill_tokens_computed as f64,
        );
        counter("kv_blocks_allocated_total", "KV blocks allocated", self.blocks_allocated as f64);
        counter("kv_cache_evictions_total", "KV block evictions", self.cache_evictions as f64);
        counter("adapter_loads_total", "Adapter weight loads", self.adapter_loads as f64);
        counter(
            "adapter_evictions_total",
            "Idle adapters evicted from the unified memory budget",
            self.adapter_evictions as f64,
        );
        counter(
            "adapter_load_stall_steps_total",
            "Scheduler steps where admission stalled on an adapter load",
            self.adapter_load_stall_steps as f64,
        );
        counter(
            "adapter_demotions_total",
            "Device evictions that parked adapter weights in the host tier",
            self.adapter_demotions as f64,
        );
        counter(
            "adapter_promotions_total",
            "Adapter loads served from the host tier (setup cost skipped)",
            self.adapter_promotions as f64,
        );
        counter(
            "adapter_host_drops_total",
            "Host-tier adapter entries dropped under host pressure",
            self.adapter_host_drops as f64,
        );
        counter(
            "adapter_prefetches_total",
            "Adapter loads started by the scheduler prefetch pass",
            self.adapter_prefetches as f64,
        );
        counter(
            "stream_subscriptions_total",
            "Streaming turn-event subscriptions taken",
            self.stream_subscriptions as f64,
        );
        counter(
            "stream_events_total",
            "Turn events emitted for watched requests",
            self.stream_events as f64,
        );
        counter(
            "stream_token_events_total",
            "Token events emitted for watched requests",
            self.stream_token_events as f64,
        );
        counter("sessions_created_total", "Sessions opened", self.sessions_created as f64);
        counter("sessions_closed_total", "Sessions deleted", self.sessions_closed as f64);
        counter(
            "sessions_expired_total",
            "Sessions removed by idle-TTL expiry",
            self.sessions_expired as f64,
        );
        counter(
            "lease_reclaims_total",
            "Session prefix leases broken under memory pressure",
            self.lease_reclaims as f64,
        );
        counter(
            "tenant_lease_breaks_total",
            "Leases broken because a tenant exceeded its leased-block budget",
            self.tenant_lease_breaks as f64,
        );

        let mut gauge = |name: &str, help: &str, v: f64| {
            s.push_str(&format!(
                "# HELP alora_serve_{name} {help}\n# TYPE alora_serve_{name} gauge\nalora_serve_{name} {v}\n"
            ));
        };
        gauge("num_requests_running", "Running requests", self.running_requests as f64);
        gauge("num_requests_waiting", "Waiting requests", self.waiting_requests as f64);
        gauge("kv_blocks_free", "Free KV blocks", self.free_blocks as f64);
        gauge(
            "adapter_resident_blocks",
            "Blocks charged to resident adapter weights",
            self.adapter_resident_blocks as f64,
        );
        gauge(
            "adapter_host_blocks",
            "Block-equivalents charged to demoted adapter weights on the host tier",
            self.adapter_host_blocks as f64,
        );
        gauge(
            "leased_blocks",
            "Blocks pinned by session prefix leases",
            self.leased_blocks as f64,
        );
        gauge("prefix_cache_hit_rate", "Token hit rate", self.cache_hit_rate());

        s.push_str(&Self::render_turn_series(&self.turn));
        s.push_str(&Self::render_stage_series(&self.stage));

        for (name, hist) in [("e2e_latency_seconds", &self.e2e_hist), ("ttft_seconds", &self.ttft_hist)]
        {
            s.push_str(&format!(
                "# HELP alora_serve_{name} Latency histogram\n# TYPE alora_serve_{name} histogram\n"
            ));
            for (bound, count) in hist.cumulative() {
                let le = if bound.is_infinite() { "+Inf".to_string() } else { format!("{bound}") };
                s.push_str(&format!("alora_serve_{name}_bucket{{le=\"{le}\"}} {count}\n"));
            }
            s.push_str(&format!("alora_serve_{name}_sum {}\n", hist.sum()));
            s.push_str(&format!("alora_serve_{name}_count {}\n", hist.count()));
        }
        s
    }

    /// Render the per-turn serving-boundary series (`alora_serve_turn*`):
    /// empty when no session turns have completed, so a fleet exposition
    /// can render its fleet-level series exactly once without colliding
    /// with the (empty) aggregated registry's.
    pub fn render_turn_series(turn: &StageLatencies) -> String {
        if turn.count() == 0 {
            return String::new();
        }
        let mut s = String::new();
        s.push_str(&format!(
            "# HELP alora_serve_turns_total Session turns completed\n# TYPE alora_serve_turns_total counter\nalora_serve_turns_total {}\n",
            turn.count()
        ));
        for (name, which, help) in [
            ("turn_ttft_seconds_mean", "ttft", "Mean per-turn time to first token"),
            ("turn_itl_seconds_mean", "itl", "Mean per-turn inter-token latency"),
            ("turn_e2e_seconds_mean", "e2e", "Mean per-turn end-to-end latency"),
        ] {
            s.push_str(&format!(
                "# HELP alora_serve_{name} {help}\n# TYPE alora_serve_{name} gauge\nalora_serve_{name} {}\n",
                turn.mean(which)
            ));
        }
        s
    }

    /// Render the per-stage-name families (coordinator pipelines) from a
    /// stage map, by reference — the cluster `/metrics` path renders its
    /// fleet-level series through this without cloning them. Label values
    /// are sanitized so the exposition stays `name{labels} value`, and
    /// de-duplicated after sanitization — two raw names collapsing to one
    /// label would emit duplicate samples, which makes Prometheus reject
    /// the whole scrape.
    pub fn render_stage_series(stage: &BTreeMap<String, StageLatencies>) -> String {
        let mut s = String::new();
        if stage.is_empty() {
            return s;
        }
        let sanitize = |s: &str| -> String {
            s.chars()
                .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
                .collect()
        };
        let mut labeled: Vec<(String, &StageLatencies)> = Vec::new();
        for (name, lat) in stage {
            let base = sanitize(name);
            let mut label = base.clone();
            let mut n = 2;
            while labeled.iter().any(|(l, _)| *l == label) {
                label = format!("{base}_{n}");
                n += 1;
            }
            labeled.push((label, lat));
        }
        for (metric, pick, ty) in [
            ("stage_requests_total", None, "counter"),
            ("stage_e2e_seconds_mean", Some("e2e"), "gauge"),
            ("stage_ttft_seconds_mean", Some("ttft"), "gauge"),
            ("stage_queue_seconds_mean", Some("queue"), "gauge"),
        ] {
            s.push_str(&format!(
                "# HELP alora_serve_{metric} Per-pipeline-stage series\n# TYPE alora_serve_{metric} {ty}\n"
            ));
            for (label, lat) in &labeled {
                let v = match pick {
                    None => lat.count() as f64,
                    Some(which) => lat.mean(which),
                };
                s.push_str(&format!(
                    "alora_serve_{metric}{{stage=\"{label}\"}} {v}\n"
                ));
            }
        }
        s
    }

    /// Compact human summary used by examples and the CLI.
    pub fn summary(&mut self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        m.insert("requests_finished".into(), self.requests_finished as f64);
        m.insert("cache_hit_rate".into(), self.cache_hit_rate());
        m.insert("throughput_tok_s".into(), self.throughput());
        for stage in STAGES {
            m.insert(format!("{stage}_mean_s"), self.all.mean(stage));
        }
        let med = self.all.e2e.median();
        m.insert("e2e_median_s".into(), med);
        m.insert("e2e_p99_s".into(), self.all.e2e.p99());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{ModelTarget, RequestId, Timeline};

    fn out(arrival: f64, sched: f64, first: f64, done: f64, n_out: usize) -> RequestOutput {
        let mut t = Timeline::new(arrival);
        t.first_scheduled = sched;
        t.first_token = first;
        t.finished = done;
        RequestOutput {
            id: RequestId(0),
            target: ModelTarget::Base,
            prompt_len: 10,
            output_tokens: vec![0; n_out],
            timeline: t,
            num_cached_tokens: 5,
            preemptions: 0,
        }
    }

    #[test]
    fn observe_populates_all_series() {
        let mut m = Metrics::new();
        m.observe_finished(&out(0.0, 1.0, 2.0, 4.0, 3));
        assert_eq!(m.all.count(), 1);
        assert_eq!(m.base.count(), 1);
        assert_eq!(m.adapter.count(), 0);
        assert_eq!(m.all.mean("queue"), 1.0);
        assert_eq!(m.all.mean("prefill"), 1.0);
        assert_eq!(m.all.mean("decode"), 2.0);
        assert_eq!(m.all.mean("ttft"), 2.0);
        assert_eq!(m.all.mean("e2e"), 4.0);
        assert_eq!(m.all.mean("inference"), 3.0);
        assert_eq!(m.all.mean("itl"), 1.0);
    }

    #[test]
    fn hit_rate_and_throughput() {
        let mut m = Metrics::new();
        m.prefill_tokens_cached = 30;
        m.prefill_tokens_computed = 10;
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        m.prompt_tokens = 100;
        m.observe_finished(&out(0.0, 0.0, 1.0, 2.0, 4));
        // tokens = 100 prompt + 4 gen; e2e sum = 2.0
        assert!((m.throughput() - 52.0).abs() < 1e-9);
    }

    #[test]
    fn prometheus_exposition_wellformed() {
        let mut m = Metrics::new();
        m.requests_received = 3;
        m.adapter_loads = 2;
        m.adapter_evictions = 1;
        m.adapter_resident_blocks = 64;
        m.observe_finished(&out(0.0, 0.1, 0.3, 0.9, 16));
        let text = m.render_prometheus();
        assert!(text.contains("alora_serve_requests_received_total 3"));
        assert!(text.contains("alora_serve_adapter_loads_total 2"));
        assert!(text.contains("alora_serve_adapter_evictions_total 1"));
        assert!(text.contains("alora_serve_adapter_load_stall_steps_total 0"));
        assert!(text.contains("alora_serve_adapter_resident_blocks 64"));
        assert!(text.contains("alora_serve_ttft_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("# TYPE alora_serve_e2e_latency_seconds histogram"));
        // every non-comment line is "name[{labels}] value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
    }

    #[test]
    fn per_stage_series_and_exposition() {
        let mut m = Metrics::new();
        m.observe_stage("draft", &out(0.0, 1.0, 2.0, 4.0, 3));
        m.observe_stage("draft", &out(0.0, 1.0, 2.0, 6.0, 3));
        m.observe_stage("eval 0?", &out(0.0, 0.5, 1.0, 2.0, 2));
        m.observe_stage("eval_0_", &out(0.0, 0.5, 1.0, 2.0, 2));
        assert_eq!(m.stage_latencies("draft").unwrap().count(), 2);
        assert_eq!(m.stage_latencies("draft").unwrap().mean("e2e"), 5.0);
        assert!(m.stage_latencies("missing").is_none());
        let text = m.render_prometheus();
        assert!(text.contains("alora_serve_stage_requests_total{stage=\"draft\"} 2"));
        // label values are sanitized to keep the exposition well-formed,
        // and post-sanitization collisions get a uniquifying suffix
        assert!(text.contains("{stage=\"eval_0_\"}"), "{text}");
        assert!(text.contains("{stage=\"eval_0__2\"}"), "{text}");
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
    }

    #[test]
    fn absorb_sums_counters_and_merges_series() {
        let mut a = Metrics::new();
        a.requests_received = 2;
        a.clock = 3.0;
        a.observe_finished(&out(0.0, 1.0, 2.0, 4.0, 3));
        a.observe_stage("draft", &out(0.0, 1.0, 2.0, 4.0, 3));
        let mut b = Metrics::new();
        b.requests_received = 5;
        b.clock = 2.0;
        b.observe_finished(&out(0.0, 1.0, 2.0, 6.0, 3));
        b.observe_stage("draft", &out(0.0, 1.0, 2.0, 6.0, 3));
        a.absorb(&b);
        assert_eq!(a.requests_received, 7);
        assert_eq!(a.requests_finished, 2);
        assert_eq!(a.clock, 3.0, "fleet clock is the max");
        assert_eq!(a.all.count(), 2);
        assert_eq!(a.stage["draft"].count(), 2);
        assert_eq!(a.e2e_hist.count(), 2);
    }

    #[test]
    fn turn_series_and_stream_counters_render_and_absorb() {
        let mut m = Metrics::new();
        // No turns: the turn families are absent entirely.
        assert!(!m.render_prometheus().contains("alora_serve_turns_total"));
        m.observe_turn(&out(0.0, 1.0, 2.0, 4.0, 3));
        m.observe_turn(&out(0.0, 1.0, 3.0, 5.0, 3));
        m.stream_subscriptions = 2;
        m.stream_events = 10;
        m.stream_token_events = 6;
        m.sessions_created = 3;
        m.sessions_closed = 1;
        m.lease_reclaims = 4;
        m.leased_blocks = 17;
        let text = m.render_prometheus();
        assert!(text.contains("alora_serve_turns_total 2"), "{text}");
        assert!(text.contains("alora_serve_turn_ttft_seconds_mean 2.5"), "{text}");
        assert!(text.contains("alora_serve_stream_subscriptions_total 2"));
        assert!(text.contains("alora_serve_stream_events_total 10"));
        assert!(text.contains("alora_serve_stream_token_events_total 6"));
        assert!(text.contains("alora_serve_sessions_created_total 3"));
        assert!(text.contains("alora_serve_sessions_closed_total 1"));
        assert!(text.contains("alora_serve_lease_reclaims_total 4"));
        assert!(text.contains("alora_serve_leased_blocks 17"));
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
        // Absorb: counters sum, the turn series sample-merges.
        let mut agg = Metrics::new();
        agg.absorb(&m);
        agg.absorb(&m);
        assert_eq!(agg.turn.count(), 4);
        assert_eq!(agg.stream_token_events, 12);
        assert_eq!(agg.sessions_created, 6);
        // Scalars-only absorb skips the series (scrape path).
        let mut fast = Metrics::new();
        fast.absorb_scalars(&m);
        assert_eq!(fast.turn.count(), 0);
        assert_eq!(fast.lease_reclaims, 4);
    }

    #[test]
    fn observe_and_absorb_share_the_stage_cardinality_cap() {
        // Adversarial/generated stage names must not grow the registry
        // unbounded — on EITHER ingestion path. `observe_stage` has capped
        // since it existed; `absorb` must apply the same fold.
        let mut src = Metrics::new();
        for i in 0..MAX_STAGE_SERIES + 50 {
            src.observe_stage(&format!("gen-{i}"), &out(0.0, 1.0, 2.0, 4.0, 3));
        }
        assert!(src.stage.len() <= MAX_STAGE_SERIES + 1, "observe path capped");
        assert!(src.stage.contains_key("__other"));

        // A second registry whose names are entirely disjoint: absorbing
        // it into the (already full) first must fold, not grow.
        let mut other = Metrics::new();
        for i in 0..100 {
            other.observe_stage(&format!("alien-{i}"), &out(0.0, 1.0, 2.0, 4.0, 3));
        }
        let total_before: usize = src.stage.values().map(|l| l.count()).sum();
        let incoming: usize = other.stage.values().map(|l| l.count()).sum();
        src.absorb(&other);
        assert!(src.stage.len() <= MAX_STAGE_SERIES + 1, "absorb path capped");
        let total_after: usize = src.stage.values().map(|l| l.count()).sum();
        assert_eq!(total_after, total_before + incoming, "no samples dropped");
        // Rendering stays duplicate-free (one sample per label).
        let text = src.render_prometheus();
        let mut seen = std::collections::BTreeSet::new();
        for line in text.lines().filter(|l| l.starts_with("alora_serve_stage_requests_total{")) {
            assert!(seen.insert(line.split_whitespace().next().unwrap().to_string()), "dup: {line}");
        }
    }

    #[test]
    fn registry_memory_bounded_under_1e5_turns() {
        // Acceptance criterion: the registry's retained-sample footprint is
        // pinned at reservoir capacity x series count no matter how many
        // turns flow through (10^5 here; a million-session run is the same
        // bound).
        use crate::util::stats::RESERVOIR_CAP;
        let mut m = Metrics::new();
        for i in 0..100_000 {
            let t0 = i as f64 * 0.01;
            m.observe_turn(&out(t0, t0 + 0.1, t0 + 0.3, t0 + 0.9, 8));
        }
        assert_eq!(m.turn.count(), 100_000, "counts stay exact");
        // 7 Samples per StageLatencies, each bounded by the reservoir cap.
        let retained = m.turn.e2e.retained()
            + m.turn.queue.retained()
            + m.turn.prefill.retained()
            + m.turn.decode.retained()
            + m.turn.ttft.retained()
            + m.turn.itl.retained()
            + m.turn.inference.retained();
        assert!(retained <= 7 * RESERVOIR_CAP, "retained={retained}");
        // Means stay exact and percentiles stay available.
        assert!(m.turn.mean("ttft") > 0.0);
        assert!(m.turn.ttft.p99() > 0.0);
    }

    #[test]
    fn tiering_counters_render_and_absorb() {
        let mut m = Metrics::new();
        m.adapter_demotions = 4;
        m.adapter_promotions = 3;
        m.adapter_host_drops = 2;
        m.adapter_prefetches = 5;
        m.adapter_host_blocks = 24;
        let text = m.render_prometheus();
        assert!(text.contains("alora_serve_adapter_demotions_total 4"), "{text}");
        assert!(text.contains("alora_serve_adapter_promotions_total 3"), "{text}");
        assert!(text.contains("alora_serve_adapter_host_drops_total 2"), "{text}");
        assert!(text.contains("alora_serve_adapter_prefetches_total 5"), "{text}");
        assert!(text.contains("alora_serve_adapter_host_blocks 24"), "{text}");
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
        let mut agg = Metrics::new();
        agg.absorb_scalars(&m);
        agg.absorb_scalars(&m);
        assert_eq!(agg.adapter_demotions, 8);
        assert_eq!(agg.adapter_promotions, 6);
        assert_eq!(agg.adapter_host_drops, 4);
        assert_eq!(agg.adapter_prefetches, 10);
        assert_eq!(agg.adapter_host_blocks, 48);
    }

    #[test]
    fn sessions_expired_counter_renders_and_absorbs() {
        let mut m = Metrics::new();
        m.sessions_expired = 5;
        let text = m.render_prometheus();
        assert!(text.contains("alora_serve_sessions_expired_total 5"), "{text}");
        let mut agg = Metrics::new();
        agg.absorb_scalars(&m);
        assert_eq!(agg.sessions_expired, 5);
    }

    #[test]
    fn routing_metrics_imbalance_and_exposition() {
        let mut r = RoutingMetrics::new(2);
        assert_eq!(r.imbalance(), 1.0, "no traffic = balanced");
        r.routed = vec![9, 3];
        r.affinity_hits = 7;
        r.affinity_fallbacks = 5;
        r.replica_failures = 1;
        r.requeued_requests = 4;
        r.orphaned_leases = 2;
        r.resticks = 3;
        assert!((r.imbalance() - 1.5).abs() < 1e-12);
        let text = r.render_prometheus();
        assert!(text.contains("router_requests_routed_total{replica=\"0\"} 9"));
        assert!(text.contains("router_affinity_hits_total 7"));
        assert!(text.contains("router_imbalance 1.5"));
        assert!(text.contains("alora_serve_replica_failures_total 1"), "{text}");
        assert!(text.contains("alora_serve_requeued_requests_total 4"), "{text}");
        assert!(text.contains("alora_serve_orphaned_leases_total 2"), "{text}");
        assert!(text.contains("alora_serve_resticks_total 3"), "{text}");
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
    }

    #[test]
    fn selfdriving_counters_render() {
        let mut r = RoutingMetrics::new(2);
        r.heartbeat_misses = 9;
        r.suspected_transitions = 2;
        r.detected_failures = 1;
        r.scale_ups = 3;
        r.scale_downs = 2;
        r.stale_sketch_decays = 7;
        let text = r.render_prometheus();
        assert!(text.contains("alora_serve_heartbeat_misses_total 9"), "{text}");
        assert!(text.contains("alora_serve_suspected_transitions_total 2"), "{text}");
        assert!(text.contains("alora_serve_detected_failures_total 1"), "{text}");
        assert!(text.contains("alora_serve_scale_ups_total 3"), "{text}");
        assert!(text.contains("alora_serve_scale_downs_total 2"), "{text}");
        assert!(text.contains("alora_serve_stale_sketch_decays_total 7"), "{text}");
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
    }

    #[test]
    fn replica_families_one_sample_per_replica() {
        let mut m0 = Metrics::new();
        m0.requests_finished = 4;
        let m1 = Metrics::new();
        m0.clock = 1.5;
        let text = Metrics::render_replica_families(&[&m0, &m1]);
        assert!(text.contains("replica_requests_finished_total{replica=\"0\"} 4"));
        assert!(text.contains("replica_requests_finished_total{replica=\"1\"} 0"));
        assert!(text.contains("replica_clock_seconds{replica=\"0\"} 1.5"));
        // exactly one HELP per family despite two replicas
        assert_eq!(text.matches("# HELP alora_serve_replica_clock_seconds").count(), 1);
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.split_whitespace().count() == 2, "bad line: {line}");
        }
    }

    #[test]
    fn summary_contains_all_stages() {
        let mut m = Metrics::new();
        m.observe_finished(&out(0.0, 1.0, 2.0, 3.0, 2));
        let s = m.summary();
        for stage in STAGES {
            assert!(s.contains_key(&format!("{stage}_mean_s")), "{stage}");
        }
    }
}
