//! Conversation sessions: the request-layer state behind the v1 serving
//! API (`POST /v1/sessions`, `POST /v1/sessions/{id}/turns`).
//!
//! A [`Session`] owns one conversation's accumulated token stream and its
//! tenant `cache_salt`. A follow-up turn submits only its **token delta**;
//! the session composes the full chain (history + delta), which is what
//! makes cross-model prefix reuse a first-class API concept: the engine
//! sees the same base-aligned chain turn after turn, instead of trusting
//! every client to resend a byte-identical prompt. Turns are strictly
//! sequential per session — one in flight at a time — mirroring a real
//! conversation.
//!
//! Turn semantics:
//! - `append = true` (default): the turn *joins* the conversation — its
//!   delta and its generated tokens extend the session history.
//! - `append = false`: a side branch — an Activated-LoRA intrinsic
//!   evaluated over the conversation (invocation tokens + verdict) whose
//!   tokens must NOT pollute the base chain. The turn still shares the
//!   history prefix (base-aligned hashing), but the history is unchanged.
//!
//! The driving logic (submission, leases, metrics) lives in
//! [`crate::session::SessionManager`]; this module is pure state so the
//! types stay usable from any layer.

use crate::kvcache::chain::ChainRef;
use crate::kvcache::prefix::{block_hashes, next_block_hash, HashContext};
use crate::request::{ModelTarget, RequestId, RequestOutput};

/// Server-scoped session identifier (issued by the session manager).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SessionId(pub u64);

/// Index of a turn within its session (0-based, strictly sequential).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TurnId(pub u32);

/// Summary of one finished turn, retained on the session for
/// `GET /v1/sessions/{id}` and for per-turn latency assertions.
#[derive(Debug, Clone)]
pub struct TurnRecord {
    pub turn: TurnId,
    pub request: RequestId,
    pub target: ModelTarget,
    /// Tokens the client actually sent for this turn (the delta).
    pub delta_len: usize,
    /// Full prompt length the engine saw (history + delta).
    pub prompt_len: usize,
    pub output_tokens: Vec<u32>,
    pub append: bool,
    pub cached_tokens: usize,
    pub cache_hit_rate: f64,
    pub ttft_s: f64,
    pub itl_s: f64,
    pub e2e_s: f64,
    pub queue_s: f64,
    pub preemptions: u32,
}

/// The one turn a session may have in flight.
#[derive(Debug, Clone)]
struct PendingTurn {
    turn: TurnId,
    request: RequestId,
    target: ModelTarget,
    delta: Vec<u32>,
    append: bool,
    prompt_len: usize,
}

/// One conversation's state: tenant salt, accumulated tokens, finished
/// turns, and the in-flight turn (if any).
#[derive(Debug, Clone)]
pub struct Session {
    pub id: SessionId,
    /// Multi-tenant cache salt every turn submits under (vLLM semantics:
    /// nonzero salts partition the prefix cache per tenant).
    pub cache_salt: u64,
    /// Accumulated conversation tokens (every appended turn's delta +
    /// generated output, in order). This is the chain the server
    /// reconstructs for each delta submission.
    tokens: Vec<u32>,
    /// Cached interned block-hash chain over `tokens` under the base
    /// context + `cache_salt` — the chain every base follow-up turn (and,
    /// via base-aligned hashing, every pre-activation aLoRA block)
    /// presents. `tokens` is append-only, so the cache is always a valid
    /// prefix and each turn extends it by O(delta) arena appends instead
    /// of rehashing — or copying — the conversation (DESIGN.md §16, §17).
    chain: ChainRef,
    turns: Vec<TurnRecord>,
    pending: Option<PendingTurn>,
    /// The most recent turn's request id — the stickiness peer a cluster
    /// routes follow-up turns by (same replica = warm prefix).
    pub last_request: Option<RequestId>,
    /// Blocks pinned by the session's prefix lease after the last turn
    /// (informational; the KV manager owns the actual pins).
    pub leased_blocks: usize,
    /// Virtual-clock stamp of the last turn submitted or completed (or
    /// session creation) — what idle-TTL expiry measures against.
    pub last_activity: f64,
    /// The target a forked child was created to serve (None = plain
    /// session, runs base). Turns that don't name an adapter run against
    /// this, so a K-way fork over K adapters needs no per-turn adapter
    /// plumbing in the client.
    pub preferred_target: Option<ModelTarget>,
}

impl Session {
    pub fn new(id: SessionId, cache_salt: u64) -> Self {
        Session {
            id,
            cache_salt,
            tokens: Vec::new(),
            chain: ChainRef::empty(),
            turns: Vec::new(),
            pending: None,
            last_request: None,
            leased_blocks: 0,
            last_activity: 0.0,
            preferred_target: None,
        }
    }

    /// Child session created by a fork (`POST /v1/sessions/{id}/fork`):
    /// shares the parent's accumulated tokens and — O(1), the chain is
    /// arena-interned — its hash-chain handle, so K children reference ONE
    /// copy of the conversation prefix instead of K. Turn records and
    /// in-flight state start fresh (the fork point begins a new branch);
    /// stickiness inherits the parent's last request so the child's first
    /// turn lands on the replica where the prefix lives.
    pub fn forked(
        id: SessionId,
        parent: &Session,
        preferred_target: Option<ModelTarget>,
        now: f64,
    ) -> Self {
        Session {
            id,
            cache_salt: parent.cache_salt,
            tokens: parent.tokens.clone(),
            chain: parent.chain.clone(),
            turns: Vec::new(),
            pending: None,
            last_request: parent.last_request,
            leased_blocks: 0,
            last_activity: now,
            preferred_target,
        }
    }

    /// The accumulated conversation token stream.
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    pub fn history_len(&self) -> usize {
        self.tokens.len()
    }

    pub fn turns(&self) -> &[TurnRecord] {
        &self.turns
    }

    pub fn num_turns(&self) -> usize {
        self.turns.len()
    }

    /// The in-flight turn's request id, if a turn is running.
    pub fn in_flight(&self) -> Option<RequestId> {
        self.pending.as_ref().map(|p| p.request)
    }

    /// Compose the full prompt for a delta turn: history + delta. Errors
    /// if a turn is already in flight (strictly sequential) or if both
    /// history and delta are empty (nothing to run).
    pub fn compose_prompt(&self, delta: &[u32]) -> anyhow::Result<Vec<u32>> {
        if let Some(p) = &self.pending {
            anyhow::bail!(
                "session {}: turn {} is still in flight",
                self.id.0,
                p.turn.0
            );
        }
        let mut prompt = Vec::with_capacity(self.tokens.len() + delta.len());
        prompt.extend_from_slice(&self.tokens);
        prompt.extend_from_slice(delta);
        anyhow::ensure!(
            !prompt.is_empty(),
            "session {}: empty turn (no history and an empty delta)",
            self.id.0
        );
        Ok(prompt)
    }

    /// Record a submitted turn as in flight. The caller submits first and
    /// only then commits, so a rejected submission leaves no state behind.
    pub fn note_submitted(
        &mut self,
        request: RequestId,
        target: ModelTarget,
        delta: Vec<u32>,
        append: bool,
        prompt_len: usize,
    ) -> TurnId {
        debug_assert!(self.pending.is_none(), "turn already in flight");
        let turn = TurnId(self.turns.len() as u32);
        self.pending = Some(PendingTurn { turn, request, target, delta, append, prompt_len });
        turn
    }

    /// Apply the finished output of the in-flight turn: extend the history
    /// (append turns only), retire the pending state, and return the
    /// turn's record.
    pub fn apply_finished(&mut self, out: &RequestOutput) -> anyhow::Result<TurnRecord> {
        let pending_req = self
            .pending
            .as_ref()
            .map(|p| p.request)
            .ok_or_else(|| anyhow::anyhow!("session {}: no turn in flight", self.id.0))?;
        // Check before consuming: a mismatched output must not destroy
        // the in-flight turn it doesn't belong to.
        anyhow::ensure!(
            pending_req == out.id,
            "session {}: output {:?} does not match in-flight turn {:?}",
            self.id.0,
            out.id,
            pending_req
        );
        let p = self.pending.take().expect("checked above");
        let record = TurnRecord {
            turn: p.turn,
            request: p.request,
            target: p.target,
            delta_len: p.delta.len(),
            prompt_len: p.prompt_len,
            output_tokens: out.output_tokens.clone(),
            append: p.append,
            cached_tokens: out.num_cached_tokens,
            cache_hit_rate: out.cache_hit_rate(),
            ttft_s: out.timeline.ttft(),
            itl_s: out.itl(),
            e2e_s: out.timeline.e2e(),
            queue_s: out.timeline.queue_time(),
            preemptions: out.preemptions,
        };
        if p.append {
            self.tokens.extend_from_slice(&p.delta);
            self.tokens.extend_from_slice(&out.output_tokens);
        }
        self.last_request = Some(p.request);
        self.turns.push(record.clone());
        Ok(record)
    }

    /// The session's base-context hash chain over its full blocks,
    /// extended incrementally: only blocks beyond the cached frontier are
    /// hashed, so the amortized cost per turn is O(delta), independent of
    /// conversation length. Returns an O(1) handle clone — sharing the
    /// chain with leases and routing never copies hashes.
    pub fn cached_chain(&mut self, block_size: usize) -> ChainRef {
        let total = self.tokens.len() / block_size;
        debug_assert!(
            self.chain.len() <= total,
            "chain cache ahead of tokens (block_size changed mid-session?)"
        );
        if self.chain.len() < total {
            let ctx = HashContext { cache_salt: self.cache_salt, ..HashContext::base() };
            let mut parent = self.chain.last();
            let mut delta = Vec::with_capacity(total - self.chain.len());
            for idx in self.chain.len()..total {
                let h = next_block_hash(parent, &self.tokens, idx, block_size, &ctx);
                delta.push(h);
                parent = Some(h);
            }
            self.chain = self.chain.extend(&delta);
        }
        self.chain.clone()
    }

    /// Full-prompt hash chain for a turn over `prompt` (history + delta)
    /// under the turn's `ctx`, reusing the cached history chain whenever
    /// every history block hashes identically under `ctx`: the base
    /// context itself, or a base-aligned aLoRA whose activation starts at
    /// or after the history frontier (all history blocks pre-activation).
    /// Anything else — standard LoRA, base-aligned hashing off, an
    /// invocation reaching back into history — falls back to a full
    /// rehash; those chains are salted differently block-for-block.
    ///
    /// The result is byte-identical to `block_hashes(prompt, bs, ctx)` by
    /// construction (pinned by the chain-extension property test).
    pub fn turn_chain(
        &mut self,
        prompt: &[u32],
        block_size: usize,
        ctx: &HashContext,
    ) -> ChainRef {
        debug_assert!(
            prompt.len() >= self.tokens.len() && prompt[..self.tokens.len()] == self.tokens[..],
            "turn prompt must extend the session history"
        );
        let hist_blocks = self.tokens.len() / block_size;
        let reusable = ctx.cache_salt == self.cache_salt
            && (ctx.adapter_id.is_none()
                || (ctx.is_alora
                    && ctx.base_aligned
                    && ctx.inv_start >= hist_blocks * block_size));
        if !reusable {
            return ChainRef::from_hashes(&block_hashes(prompt, block_size, ctx));
        }
        // Delta path: share the cached history chain's nodes and append
        // only the turn's blocks — zero full-chain copies (an aLoRA
        // `append:false` branch simply interns a second child of the same
        // history node).
        let base = self.cached_chain(block_size);
        let total = prompt.len() / block_size;
        let mut parent = base.last();
        let mut delta = Vec::with_capacity(total.saturating_sub(hist_blocks));
        for idx in hist_blocks..total {
            let h = next_block_hash(parent, prompt, idx, block_size, ctx);
            delta.push(h);
            parent = Some(h);
        }
        base.extend(&delta)
    }

    /// Drop the in-flight turn without applying it (client abandoned the
    /// request). The history stays at the last completed turn; the engine
    /// keeps running the orphaned request, whose output the caller must
    /// discard. Returns the abandoned request id.
    pub fn abort_pending(&mut self) -> Option<RequestId> {
        self.pending.take().map(|p| p.request)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{RequestId, Timeline};

    fn out(id: u64, tokens: Vec<u32>, cached: usize) -> RequestOutput {
        let mut t = Timeline::new(0.0);
        t.first_scheduled = 0.1;
        t.first_token = 0.2;
        t.finished = 0.5;
        RequestOutput {
            id: RequestId(id),
            target: ModelTarget::Base,
            prompt_len: 4,
            output_tokens: tokens,
            timeline: t,
            num_cached_tokens: cached,
            preemptions: 0,
        }
    }

    #[test]
    fn delta_turns_accumulate_history() {
        let mut s = Session::new(SessionId(1), 7);
        let p1 = s.compose_prompt(&[1, 2, 3]).unwrap();
        assert_eq!(p1, vec![1, 2, 3]);
        let t = s.note_submitted(RequestId(10), ModelTarget::Base, vec![1, 2, 3], true, 3);
        assert_eq!(t, TurnId(0));
        assert_eq!(s.in_flight(), Some(RequestId(10)));
        let rec = s.apply_finished(&out(10, vec![4, 5], 0)).unwrap();
        assert_eq!(rec.output_tokens, vec![4, 5]);
        assert_eq!(s.tokens(), &[1, 2, 3, 4, 5]);
        assert_eq!(s.last_request, Some(RequestId(10)));
        // Second turn composes history + delta.
        let p2 = s.compose_prompt(&[6]).unwrap();
        assert_eq!(p2, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn side_branch_turn_leaves_history_untouched() {
        let mut s = Session::new(SessionId(2), 0);
        s.note_submitted(RequestId(1), ModelTarget::Base, vec![1, 2], true, 2);
        s.apply_finished(&out(1, vec![3], 0)).unwrap();
        // Non-append (intrinsic) branch over the same history.
        let p = s.compose_prompt(&[9, 9]).unwrap();
        assert_eq!(p, vec![1, 2, 3, 9, 9]);
        s.note_submitted(RequestId(2), ModelTarget::Base, vec![9, 9], false, 5);
        let rec = s.apply_finished(&out(2, vec![7], 2)).unwrap();
        assert!(!rec.append);
        assert_eq!(s.tokens(), &[1, 2, 3], "branch must not pollute the chain");
        assert_eq!(s.num_turns(), 2);
        assert_eq!(s.last_request, Some(RequestId(2)));
    }

    #[test]
    fn one_turn_in_flight_at_a_time() {
        let mut s = Session::new(SessionId(3), 0);
        s.note_submitted(RequestId(1), ModelTarget::Base, vec![1], true, 1);
        let err = s.compose_prompt(&[2]).unwrap_err();
        assert!(err.to_string().contains("in flight"), "{err}");
        // Aborting clears the way; history unchanged.
        assert_eq!(s.abort_pending(), Some(RequestId(1)));
        assert!(s.compose_prompt(&[2]).is_ok());
        assert_eq!(s.history_len(), 0);
    }

    #[test]
    fn property_incremental_chain_matches_full_rehash() {
        // Satellite (a): for random delta sequences, the incrementally
        // extended chain is byte-identical to a full rehash — under the
        // base context, under a base-aligned aLoRA activating in the
        // delta, and under contexts that force the fallback path.
        use crate::kvcache::prefix::block_hashes;
        use crate::util::prop;
        prop::check("session-chain-incremental", 20, |rng, _| {
            let bs = *[4usize, 8, 16].get(rng.next_below(3) as usize).unwrap();
            let salt = rng.next_below(3);
            let mut s = Session::new(SessionId(1), salt);
            for turn in 0..rng.range(2, 8) {
                let delta: Vec<u32> = (0..rng.range(1, 5 * bs as u64) as usize)
                    .map(|_| rng.next_below(1000) as u32)
                    .collect();
                let prompt = s.compose_prompt(&delta).unwrap();
                // Base-context turn chain == full rehash.
                let base_ctx = HashContext { cache_salt: salt, ..HashContext::base() };
                let inc = s.turn_chain(&prompt, bs, &base_ctx);
                let full = block_hashes(&prompt, bs, &base_ctx);
                if inc.hashes() != full {
                    return Err(format!("turn {turn}: base chain diverged"));
                }
                // Base-aligned aLoRA activating inside the delta: history
                // blocks reuse the cache, the rest hash under the salt.
                let a_ctx = HashContext {
                    adapter_id: Some(3),
                    is_alora: true,
                    inv_start: s.history_len()
                        + rng.next_below(delta.len() as u64 + 1) as usize,
                    base_aligned: true,
                    cache_salt: salt,
                };
                if s.turn_chain(&prompt, bs, &a_ctx).hashes()
                    != block_hashes(&prompt, bs, &a_ctx)
                {
                    return Err(format!("turn {turn}: alora chain diverged"));
                }
                // Standard LoRA forces the full-rehash fallback; still equal.
                let l_ctx = HashContext {
                    adapter_id: Some(3),
                    is_alora: false,
                    inv_start: 0,
                    base_aligned: true,
                    cache_salt: salt,
                };
                if s.turn_chain(&prompt, bs, &l_ctx).hashes()
                    != block_hashes(&prompt, bs, &l_ctx)
                {
                    return Err(format!("turn {turn}: lora chain diverged"));
                }
                // Apply the turn (with some generated tokens) and check the
                // history cache still matches a from-scratch hash.
                let gen: Vec<u32> =
                    (0..rng.range(1, 12) as usize).map(|_| rng.next_below(1000) as u32).collect();
                let rid = RequestId(100 + turn);
                s.note_submitted(rid, ModelTarget::Base, delta, true, prompt.len());
                s.apply_finished(&out(rid.0, gen, 0)).unwrap();
                let want = block_hashes(
                    s.tokens(),
                    bs,
                    &HashContext { cache_salt: salt, ..HashContext::base() },
                );
                if s.cached_chain(bs).hashes() != want {
                    return Err(format!("turn {turn}: history cache diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn forked_child_shares_history_and_chain_but_not_turn_state() {
        use crate::adapter::AdapterId;
        let mut s = Session::new(SessionId(5), 3);
        s.note_submitted(RequestId(1), ModelTarget::Base, (0..40).collect(), true, 40);
        s.apply_finished(&out(1, vec![7, 8], 0)).unwrap();
        let parent_chain = s.cached_chain(4);
        let child = Session::forked(
            SessionId(6),
            &s,
            Some(ModelTarget::Adapter(AdapterId(1))),
            2.5,
        );
        assert_eq!(child.id, SessionId(6));
        assert_eq!(child.cache_salt, s.cache_salt, "tenant salt inherited");
        assert_eq!(child.tokens(), s.tokens(), "history shared at the fork point");
        assert_eq!(child.num_turns(), 0, "turn records start fresh");
        assert_eq!(child.in_flight(), None);
        assert_eq!(child.leased_blocks, 0, "pins are the manager's to take");
        assert_eq!(child.last_request, s.last_request, "stickiness inherited");
        assert_eq!(child.last_activity, 2.5);
        assert_eq!(child.preferred_target, Some(ModelTarget::Adapter(AdapterId(1))));
        // The chain handle was cloned, not rebuilt: same interned hashes.
        let mut child = child;
        assert_eq!(child.cached_chain(4).hashes(), parent_chain.hashes());
        // The branch is independent: a child turn must not touch the parent.
        let p = child.compose_prompt(&[9]).unwrap();
        child.note_submitted(RequestId(2), ModelTarget::Base, vec![9], true, p.len());
        child.apply_finished(&out(2, vec![1], 0)).unwrap();
        assert_eq!(s.history_len(), 42);
        assert_eq!(child.history_len(), 44, "child branch diverged alone");
    }

    #[test]
    fn rejects_empty_turn_and_mismatched_output() {
        let mut s = Session::new(SessionId(4), 0);
        assert!(s.compose_prompt(&[]).is_err(), "no history, empty delta");
        s.note_submitted(RequestId(1), ModelTarget::Base, vec![1], true, 1);
        assert!(s.apply_finished(&out(99, vec![2], 0)).is_err(), "wrong id");
        // A mismatched output leaves the in-flight turn intact.
        assert_eq!(s.in_flight(), Some(RequestId(1)));
        assert!(s.apply_finished(&out(1, vec![2], 0)).is_ok());
    }
}
