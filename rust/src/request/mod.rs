//! Request lifecycle: queue → prefill → decode → finished (paper §2.4).
//!
//! Timestamps are virtual seconds supplied by the engine clock (identical
//! pipeline for the simulator and the real PJRT path), and the Table-2
//! metrics (E2E, queue, prefill, decode, TTFT, ITL) are derived exactly as
//! the paper defines them.

pub mod session;

use crate::adapter::AdapterId;
use crate::kvcache::chain::ChainRef;
use crate::kvcache::prefix::HashContext;

pub use session::{Session, SessionId, TurnId, TurnRecord};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// What the request runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelTarget {
    Base,
    Adapter(AdapterId),
}

impl ModelTarget {
    pub fn adapter(&self) -> Option<AdapterId> {
        match self {
            ModelTarget::Base => None,
            ModelTarget::Adapter(a) => Some(*a),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    /// In the scheduler's waiting queue.
    Waiting,
    /// Scheduled on the executor (prefilling or decoding).
    Running,
    /// Evicted under memory pressure; will restart prefill.
    Preempted,
    Finished,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// Number of tokens to generate (paper evaluates fixed lengths,
    /// e.g. 16 for adapter evaluation, 256 for base generation).
    pub max_new_tokens: u32,
    /// Greedy when false (the only mode the tiny artifact needs; the
    /// simulator ignores sampled values entirely).
    pub sample: bool,
    pub temperature: f32,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { max_new_tokens: 16, sample: false, temperature: 1.0 }
    }
}

/// Lifecycle timestamps (virtual seconds). f64::NAN = not yet reached.
#[derive(Debug, Clone, Copy)]
pub struct Timeline {
    /// Request handed to the engine.
    pub arrival: f64,
    /// First scheduled onto the executor (start of model execution).
    pub first_scheduled: f64,
    /// First output token produced (start of generation).
    pub first_token: f64,
    /// Completed.
    pub finished: f64,
}

impl Timeline {
    pub fn new(arrival: f64) -> Self {
        Timeline {
            arrival,
            first_scheduled: f64::NAN,
            first_token: f64::NAN,
            finished: f64::NAN,
        }
    }

    /// Queue time: input → start of model execution.
    pub fn queue_time(&self) -> f64 {
        self.first_scheduled - self.arrival
    }

    /// Prefill time: start of model execution → start of generation.
    pub fn prefill_time(&self) -> f64 {
        self.first_token - self.first_scheduled
    }

    /// Decode time: start of generation → completion.
    pub fn decode_time(&self) -> f64 {
        self.finished - self.first_token
    }

    /// TTFT = queue + prefill.
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// E2E = queue + prefill + decode.
    pub fn e2e(&self) -> f64 {
        self.finished - self.arrival
    }

    /// ITL = decode time / (output tokens - 1).
    pub fn itl(&self, n_output_tokens: u32) -> f64 {
        if n_output_tokens <= 1 {
            0.0
        } else {
            self.decode_time() / (n_output_tokens - 1) as f64
        }
    }
}

/// One inference request moving through the engine.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub target: ModelTarget,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    pub state: State,
    pub timeline: Timeline,

    // -- engine-maintained progress --------------------------------------
    /// Generated tokens so far.
    pub output_tokens: Vec<u32>,
    /// Tokens whose KV is computed (cached prefix + prefilled + decoded).
    pub num_computed_tokens: usize,
    /// Tokens served from prefix cache at admission (engine sets this).
    pub num_cached_tokens: usize,
    /// aLoRA activation point (absolute token index); prompt length for
    /// base/LoRA (i.e. "no pre-activation masking").
    pub activation_start: usize,
    /// Number of preemptions suffered (re-prefills).
    pub preemptions: u32,
    /// The admission gate cold-loaded this request's adapter weights (set
    /// when the load happens, cleared once the admission lands). Keeps the
    /// residency hit-rate honest across a same-step capacity rollback: the
    /// retry must not count the adapter this request just paged in as
    /// "already warm".
    pub admission_cold_load: bool,
    /// Block-hash salting policy (set by the engine at submit time from
    /// the adapter registry + feature flag).
    pub hash_ctx: HashContext,
    /// Incrementally-maintained interned chain of full-block hashes over
    /// `all_tokens()` (engine-maintained; avoids O(n²) rehashing). A
    /// [`ChainRef`] handle: extending by a decode block is O(1) arena
    /// appends, and handing the chain to the KV manager shares nodes
    /// instead of copying a `Vec<BlockHash>`.
    pub hash_chain: ChainRef,
}

impl Request {
    pub fn new(
        id: RequestId,
        target: ModelTarget,
        prompt: Vec<u32>,
        params: SamplingParams,
        arrival: f64,
    ) -> Self {
        let prompt_len = prompt.len();
        assert!(prompt_len > 0, "empty prompt");
        assert!(params.max_new_tokens > 0, "must generate at least one token");
        Request {
            id,
            target,
            prompt,
            params,
            state: State::Waiting,
            timeline: Timeline::new(arrival),
            output_tokens: Vec::new(),
            num_computed_tokens: 0,
            num_cached_tokens: 0,
            activation_start: prompt_len,
            preemptions: 0,
            admission_cold_load: false,
            hash_ctx: HashContext::base(),
            hash_chain: ChainRef::empty(),
        }
    }

    /// Full token stream (prompt + generated so far).
    pub fn all_tokens(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.prompt.len() + self.output_tokens.len());
        v.extend_from_slice(&self.prompt);
        v.extend_from_slice(&self.output_tokens);
        v
    }

    pub fn total_len(&self) -> usize {
        self.prompt.len() + self.output_tokens.len()
    }

    /// Target total length when generation completes.
    pub fn final_len(&self) -> usize {
        self.prompt.len() + self.params.max_new_tokens as usize
    }

    /// Still in the prefill phase (hasn't produced its first token)?
    pub fn is_prefilling(&self) -> bool {
        self.output_tokens.is_empty()
    }

    /// Tokens that still need their KV computed before the next output
    /// token can be produced.
    pub fn remaining_prefill(&self) -> usize {
        self.total_len().saturating_sub(self.num_computed_tokens)
    }

    pub fn is_finished(&self) -> bool {
        self.state == State::Finished
    }

    /// Reset progress after preemption (vLLM recompute-style preemption:
    /// blocks were dropped, prefill restarts — possibly re-hitting cache).
    pub fn reset_for_recompute(&mut self) {
        self.state = State::Preempted;
        self.num_computed_tokens = 0;
        self.num_cached_tokens = 0;
        self.preemptions += 1;
    }
}

/// One per-request lifecycle event, emitted by the engine for *watched*
/// requests (see `EngineDriver::watch`) and drained incrementally each
/// step. This is the streaming surface behind
/// `POST /v1/sessions/{id}/turns` with `stream: true`: `Started` opens
/// the TTFT clock (it carries the arrival so TTFT = first `Token.clock`
/// − `arrival`), each `Token` carries its emission clock (successive
/// deltas are the inter-token latencies), and `Finished` transfers the
/// full output record exactly once.
#[derive(Debug, Clone)]
pub enum TurnEvent {
    /// First scheduled onto the executor — queueing ended at `clock`.
    Started { id: RequestId, clock: f64, arrival: f64 },
    /// One generated token (`index` = 0-based position in the output).
    Token { id: RequestId, index: u32, token: u32, clock: f64 },
    /// The request completed. `output` is a copy of the full record; the
    /// engine's finished ledger (`take_finished*`) still holds the
    /// canonical one, so non-streaming consumers are unaffected — a
    /// streaming server consumes this copy and discards the ledger's.
    Finished { id: RequestId, output: RequestOutput },
}

impl TurnEvent {
    pub fn id(&self) -> RequestId {
        match self {
            TurnEvent::Started { id, .. }
            | TurnEvent::Token { id, .. }
            | TurnEvent::Finished { id, .. } => *id,
        }
    }

    /// Virtual time the event was emitted at.
    pub fn clock(&self) -> f64 {
        match self {
            TurnEvent::Started { clock, .. } | TurnEvent::Token { clock, .. } => *clock,
            TurnEvent::Finished { output, .. } => output.timeline.finished,
        }
    }
}

/// Final per-request record handed to metrics/pipelines.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    pub id: RequestId,
    pub target: ModelTarget,
    pub prompt_len: usize,
    pub output_tokens: Vec<u32>,
    pub timeline: Timeline,
    pub num_cached_tokens: usize,
    pub preemptions: u32,
}

impl RequestOutput {
    pub fn from_request(r: &Request) -> Self {
        RequestOutput {
            id: r.id,
            target: r.target,
            prompt_len: r.prompt.len(),
            output_tokens: r.output_tokens.clone(),
            timeline: r.timeline,
            num_cached_tokens: r.num_cached_tokens,
            preemptions: r.preemptions,
        }
    }

    pub fn itl(&self) -> f64 {
        self.timeline.itl(self.output_tokens.len() as u32)
    }

    /// Prefix-cache hit rate for this request's prompt.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.prompt_len == 0 {
            0.0
        } else {
            self.num_cached_tokens.min(self.prompt_len) as f64 / self.prompt_len as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request::new(
            RequestId(1),
            ModelTarget::Base,
            vec![1, 2, 3, 4],
            SamplingParams { max_new_tokens: 8, ..Default::default() },
            10.0,
        )
    }

    #[test]
    fn timeline_metrics_match_definitions() {
        let mut t = Timeline::new(10.0);
        t.first_scheduled = 12.0;
        t.first_token = 15.0;
        t.finished = 20.0;
        assert_eq!(t.queue_time(), 2.0);
        assert_eq!(t.prefill_time(), 3.0);
        assert_eq!(t.decode_time(), 5.0);
        assert_eq!(t.ttft(), 5.0);
        assert_eq!(t.e2e(), 10.0);
        assert!((t.itl(6) - 1.0).abs() < 1e-12);
        assert_eq!(t.itl(1), 0.0);
    }

    #[test]
    fn progress_accounting() {
        let mut r = req();
        assert!(r.is_prefilling());
        assert_eq!(r.remaining_prefill(), 4);
        r.num_computed_tokens = 4;
        assert_eq!(r.remaining_prefill(), 0);
        r.output_tokens.push(42);
        assert!(!r.is_prefilling());
        assert_eq!(r.total_len(), 5);
        assert_eq!(r.final_len(), 12);
        assert_eq!(r.all_tokens(), vec![1, 2, 3, 4, 42]);
    }

    #[test]
    fn preemption_resets_progress() {
        let mut r = req();
        r.num_computed_tokens = 4;
        r.num_cached_tokens = 2;
        r.reset_for_recompute();
        assert_eq!(r.state, State::Preempted);
        assert_eq!(r.num_computed_tokens, 0);
        assert_eq!(r.preemptions, 1);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn rejects_empty_prompt() {
        Request::new(RequestId(0), ModelTarget::Base, vec![], Default::default(), 0.0);
    }

    #[test]
    fn output_record_hit_rate() {
        let mut r = req();
        r.num_cached_tokens = 2;
        let out = RequestOutput::from_request(&r);
        assert!((out.cache_hit_rate() - 0.5).abs() < 1e-12);
    }
}
