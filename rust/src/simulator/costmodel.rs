//! H100 step-time cost model for the discrete-event simulator.
//!
//! The simulator executes the *real* L3 code (scheduler, block manager,
//! base-aligned hashing); only the GPU step duration is modeled. The model
//! follows the standard serving roofline:
//!
//! - **Prefill** is compute-bound: `2 · P · T` FLOPs for `T` new tokens
//!   over `P` parameters, plus the quadratic attention term, divided by
//!   achievable FLOPs (`peak · MFU · TP-efficiency`). Adapter matmuls add
//!   `≈ 4 · L · d · r · 3` FLOPs per adapted token (rank-r down+up on
//!   Q/K/V) — negligible, as the paper observes, but modeled.
//! - **Decode** is memory-bound: every step streams the weights plus the
//!   batch's KV history from HBM; `max(bytes / bw, flops / peak)`.
//! - **Block-table overhead**: each new PagedAttention block allocation
//!   costs a small constant (page-table update + allocator) — this is the
//!   mechanism behind the paper's observed decode-time savings from fewer
//!   allocations (§4.2: "Increased KV-cache reuse ... decreases the number
//!   of new PagedAttention block allocations ... in turn decreasing decode
//!   time").
//! - **Fixed step launch overhead**: kernel-launch + scheduler sync per
//!   engine step.
//!
//! Absolute numbers are *not* calibrated to the authors' testbed (we do
//! not have one); ratios between LoRA and aLoRA runs — which is what every
//! figure reports — depend only on how much work each policy performs.

use crate::config::EngineConfig;

/// Per-step work summary handed to the model by the SimExecutor.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepWork {
    /// New prefill tokens computed this step (sum over prefill chunks).
    pub prefill_tokens: usize,
    /// Σ context length attended over by prefill tokens (for the
    /// quadratic term): for a chunk [s, s+c) of a request, this adds
    /// c·s + c·(c+1)/2 ≈ tokens × average history.
    pub prefill_ctx_tokens: f64,
    /// Number of sequences doing a pure decode step.
    pub decode_seqs: usize,
    /// Σ context lengths of decoding sequences (KV bytes streamed).
    pub decode_ctx_tokens: f64,
    /// Decode tokens produced by *adapted* (LoRA/aLoRA-active) sequences.
    pub adapted_tokens: usize,
    /// New KV blocks allocated while packing this step.
    pub new_blocks: usize,
}

#[derive(Debug, Clone)]
pub struct CostModel {
    /// Achievable FLOP/s for prefill.
    flops: f64,
    /// Achievable bytes/s for decode weight+KV streaming.
    bw: f64,
    /// Model parameters.
    p: f64,
    /// Bytes per parameter.
    wbytes: f64,
    /// KV bytes per token.
    kv_bytes: f64,
    /// FLOPs per adapted token (adapter correction on Q/K/V).
    adapter_flops_per_tok: f64,
    /// d_model (for the attention quadratic term).
    d_model: f64,
    n_layers: f64,
    /// Tokens per KV block (for migrate-vs-recompute comparisons).
    block_size: usize,
    /// Per-new-block constant (s).
    pub block_alloc_cost: f64,
    /// Per-step constant (s): launch + host sync.
    pub step_overhead: f64,
    /// Cross-replica interconnect bandwidth for KV migration, bytes/s
    /// (200 Gb/s InfiniBand-class ≈ 25 GB/s effective).
    pub migration_bw: f64,
    /// Fixed per-migration setup cost (s): control-plane round trip,
    /// dest-side block registration, transfer kickoff. This constant is
    /// what creates the migrate-vs-recompute crossover — both the
    /// per-block transfer and the per-token prefill are linear, and
    /// transfer is the cheaper slope, so without a fixed cost migration
    /// would always win.
    pub migration_setup: f64,
    /// Host→device bandwidth for adapter weight loads, bytes/s
    /// (DESIGN.md §20). Unlike the migration constants this is a CONFIG
    /// knob (`cache.adapter_load_bw`): 0.0 — the default — models
    /// instantaneous loads, preserving PR-3 accounting bit-for-bit.
    pub adapter_load_bw: f64,
    /// Fixed per-load setup cost (s) from `cache.adapter_load_setup`:
    /// host-side staging + descriptor setup. A host-tier promotion skips
    /// it — the weights are already staged and pinned (§20).
    pub adapter_load_setup: f64,
}

impl CostModel {
    pub fn new(cfg: &EngineConfig) -> Self {
        let m = &cfg.model;
        let g = &cfg.gpu;
        let r = m.alora_rank as f64;
        CostModel {
            flops: g.total_flops() * g.prefill_mfu,
            bw: g.total_bw() * g.decode_membw_util,
            p: m.n_params,
            wbytes: m.dtype_bytes as f64,
            kv_bytes: m.kv_bytes_per_token(),
            // Q,K,V each: d·r down + r·d up, ×2 FLOPs per MAC.
            adapter_flops_per_tok: 3.0 * 2.0 * 2.0 * m.d_model as f64 * r * m.n_layers as f64,
            d_model: m.d_model as f64,
            n_layers: m.n_layers as f64,
            block_size: cfg.cache.block_size as usize,
            block_alloc_cost: 2.0e-6,
            step_overhead: 40.0e-6,
            migration_bw: 25.0e9,
            migration_setup: 5.0e-3,
            adapter_load_bw: cfg.cache.adapter_load_bw,
            adapter_load_setup: cfg.cache.adapter_load_setup,
        }
    }

    /// Linear (weight) FLOPs for `t` tokens.
    fn linear_flops(&self, t: f64) -> f64 {
        2.0 * self.p * t
    }

    /// Attention score+value FLOPs for `t` new tokens against `ctx` total
    /// context tokens: 2 matmuls × 2 FLOPs × d_model per (token, ctx).
    fn attn_flops(&self, ctx_tokens: f64) -> f64 {
        4.0 * self.n_layers * self.d_model * ctx_tokens
    }

    /// Modeled duration of one engine step, seconds.
    pub fn step_time(&self, w: &StepWork) -> f64 {
        if w.prefill_tokens == 0 && w.decode_seqs == 0 {
            return 0.0;
        }
        let mut t = self.step_overhead;

        // -- prefill: compute-bound ---------------------------------------
        if w.prefill_tokens > 0 {
            let flops = self.linear_flops(w.prefill_tokens as f64)
                + self.attn_flops(w.prefill_ctx_tokens)
                + self.adapter_flops_per_tok * w.prefill_tokens as f64;
            t += flops / self.flops;
        }

        // -- decode: memory-bound (weights once per step + KV per seq) ----
        if w.decode_seqs > 0 {
            let weight_bytes = self.p * self.wbytes;
            let kv_read = self.kv_bytes * w.decode_ctx_tokens;
            let bytes = weight_bytes + kv_read;
            let flops = self.linear_flops(w.decode_seqs as f64)
                + self.attn_flops(w.decode_ctx_tokens)
                + self.adapter_flops_per_tok * w.adapted_tokens as f64;
            t += (bytes / self.bw).max(flops / self.flops);
        }

        // -- paging ---------------------------------------------------------
        t += self.block_alloc_cost * w.new_blocks as f64;
        t
    }

    /// Convenience: full uninterrupted prefill of `n` tokens starting from
    /// `cached` computed tokens (used in unit tests / sanity checks).
    pub fn prefill_time(&self, new_tokens: usize, cached: usize) -> f64 {
        let t = new_tokens as f64;
        let ctx = t * cached as f64 + t * (t + 1.0) / 2.0;
        self.step_time(&StepWork {
            prefill_tokens: new_tokens,
            prefill_ctx_tokens: ctx,
            ..Default::default()
        })
    }

    // -- cross-replica prefix migration (DESIGN.md §18) ---------------------

    /// Modeled time to ship `blocks` KV blocks to another replica:
    /// fixed setup plus bytes over the interconnect. Charged to the
    /// destination's clock by `Cluster::migrate_lease`, so the transfer
    /// shows up honestly in the next turn's TTFT.
    pub fn migration_time(&self, blocks: usize) -> f64 {
        let kv_bytes_per_block = self.kv_bytes * self.block_size as f64;
        self.migration_setup + blocks as f64 * kv_bytes_per_block / self.migration_bw
    }

    /// The migrate-vs-recompute decision: transfer the chain's blocks only
    /// when doing so is strictly cheaper than prefilling the same span
    /// from token zero. Per-block transfer is the cheaper slope (~105 µs
    /// vs ~590 µs per granite-8b block), so the fixed setup cost sets the
    /// crossover at roughly a dozen blocks: short prefixes recompute,
    /// long conversations migrate.
    pub fn migration_wins(&self, blocks: usize) -> bool {
        self.migration_time(blocks) < self.prefill_time(blocks * self.block_size, 0)
    }

    /// Batched multi-lease transfer (autoscale-down / drain evacuation,
    /// DESIGN.md §19): `k` chains totaling `blocks` KV blocks ship as one
    /// transfer, paying `migration_setup` once instead of `k` times. With
    /// `k <= 1` this is exactly [`CostModel::migration_time`].
    pub fn batch_migration_time(&self, blocks: usize) -> f64 {
        self.migration_time(blocks)
    }

    /// Membership test for a batch that has already paid its setup: the
    /// marginal cost of adding this chain is pure per-block transfer, so
    /// the crossover sits lower than the standalone
    /// [`CostModel::migration_wins`] — chains too short to justify their
    /// own control-plane round trip still ride along for free.
    pub fn batch_migration_member_wins(&self, blocks: usize) -> bool {
        if blocks == 0 {
            return false;
        }
        let kv_bytes_per_block = self.kv_bytes * self.block_size as f64;
        blocks as f64 * kv_bytes_per_block / self.migration_bw
            < self.prefill_time(blocks * self.block_size, 0)
    }

    // -- tiered adapter memory (DESIGN.md §20) ------------------------------

    /// Modeled host→device transfer time for a cold adapter's `blocks`
    /// weight pages: fixed setup plus bytes over the link, exactly
    /// analogous to [`CostModel::migration_time`]. Returns 0.0 when
    /// `adapter_load_bw` is 0.0 (the default): loads are instantaneous
    /// accounting and the tiering state machine collapses to PR-3
    /// behavior, bit-identical.
    pub fn adapter_load_time(&self, blocks: usize) -> f64 {
        if self.adapter_load_bw <= 0.0 {
            return 0.0;
        }
        let bytes_per_block = self.kv_bytes * self.block_size as f64;
        self.adapter_load_setup + blocks as f64 * bytes_per_block / self.adapter_load_bw
    }

    /// Modeled promotion time from the host tier: pure bandwidth, no
    /// setup — demoted weights stay staged and pinned on the host, so
    /// re-loading them skips the control-plane round trip a cold load
    /// pays. Strictly cheaper than [`CostModel::adapter_load_time`]
    /// whenever `adapter_load_setup > 0`; this gap is what makes
    /// demotion beat drop-and-reload (acceptance-pinned).
    pub fn adapter_promote_time(&self, blocks: usize) -> f64 {
        if self.adapter_load_bw <= 0.0 {
            return 0.0;
        }
        let bytes_per_block = self.kv_bytes * self.block_size as f64;
        blocks as f64 * bytes_per_block / self.adapter_load_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn model(name: &str) -> CostModel {
        CostModel::new(&crate::config::presets::by_name(name).unwrap())
    }

    #[test]
    fn prefill_scales_linearly_then_quadratically() {
        let m = model("granite-8b");
        let t1k = m.prefill_time(1024, 0);
        let t2k = m.prefill_time(2048, 0);
        let t64k = m.prefill_time(65536, 0);
        assert!(t2k > 1.9 * t1k && t2k < 2.6 * t1k, "near-linear at short ctx");
        // 64× tokens must cost more than 64× time (quadratic term kicks in)
        assert!(t64k > 64.0 * t1k, "attention quadratic term visible");
    }

    #[test]
    fn cached_prefix_makes_prefill_cheap() {
        let m = model("granite-8b");
        let full = m.prefill_time(65536, 0);
        let ext = m.prefill_time(16, 65520); // aLoRA: invocation only
        assert!(
            full / ext > 100.0,
            "cache reuse must dominate: full={full} ext={ext}"
        );
    }

    #[test]
    fn decode_step_is_memory_bound_at_small_batch() {
        let m = model("granite-8b");
        // 1 seq, 1k ctx: time ≈ weights/bw = 8.17e9*2 / (3.35e12*0.55)
        let t = m.step_time(&StepWork {
            decode_seqs: 1,
            decode_ctx_tokens: 1024.0,
            ..Default::default()
        });
        let expected = (8.17e9 * 2.0) / (3.35e12 * 0.55);
        assert!((t - expected).abs() / expected < 0.2, "t={t} vs {expected}");
    }

    #[test]
    fn bigger_models_slower_than_small() {
        // Per-token cost grows with model size faster than the TP degree
        // compensates for granite -> llama; mistral's 8 GPUs roughly wash
        // with llama's 4, so we only assert the granite comparisons (the
        // paper's trend "speedups scale with model size" comes from the
        // larger absolute prefill cost that cache reuse removes).
        let g = model("granite-8b");
        let l = model("llama-70b");
        let ml = model("mistral-large-2");
        let w = StepWork { prefill_tokens: 4096, prefill_ctx_tokens: 4096.0 * 2048.0, ..Default::default() };
        assert!(l.step_time(&w) > g.step_time(&w));
        assert!(ml.step_time(&w) > g.step_time(&w));
    }

    #[test]
    fn block_alloc_overhead_counts() {
        let m = model("granite-8b");
        let w0 = StepWork { decode_seqs: 4, decode_ctx_tokens: 4096.0, ..Default::default() };
        let w64 = StepWork { new_blocks: 64, ..w0 };
        let d = m.step_time(&w64) - m.step_time(&w0);
        assert!((d - 64.0 * 2.0e-6).abs() < 1e-9);
    }

    #[test]
    fn adapter_overhead_is_small_but_nonzero() {
        let m = model("granite-8b");
        let plain = m.step_time(&StepWork {
            prefill_tokens: 1024,
            prefill_ctx_tokens: 1024.0 * 512.0,
            ..Default::default()
        });
        let adapted = m.step_time(&StepWork {
            prefill_tokens: 1024,
            prefill_ctx_tokens: 1024.0 * 512.0,
            adapted_tokens: 0, // adapter flops are charged on prefill via adapter_flops_per_tok already
            ..Default::default()
        });
        // identical here; the per-token adapter term is folded into
        // prefill cost unconditionally (both LoRA and aLoRA carry it —
        // fairness per paper §4.1, which uses activation sequences in both)
        assert_eq!(plain, adapted);
    }

    #[test]
    fn empty_step_is_free() {
        let m = model("granite-8b");
        assert_eq!(m.step_time(&StepWork::default()), 0.0);
    }

    #[test]
    fn migration_crossover_short_recomputes_long_migrates() {
        let m = model("granite-8b");
        // A handful of blocks: the fixed setup dominates, prefill wins.
        assert!(!m.migration_wins(4), "short prefix must recompute");
        // A long conversation: per-block transfer is ~5x cheaper than
        // per-block prefill, so once setup amortizes migration wins —
        // and keeps winning as the prefix grows.
        assert!(m.migration_wins(64), "long prefix must migrate");
        assert!(m.migration_wins(1024));
        // Monotone linear transfer: time grows with block count.
        assert!(m.migration_time(128) > m.migration_time(64));
        assert!(m.migration_time(0) > 0.0, "setup cost never free");
    }

    #[test]
    fn batch_migration_pays_setup_once() {
        let m = model("granite-8b");
        // K sessions of B blocks each: one coalesced transfer vs K
        // per-session transfers differ by exactly (K-1) setup charges.
        let (k, b) = (8, 16);
        let per_session = k as f64 * m.migration_time(b);
        let batched = m.batch_migration_time(k * b);
        assert!(
            (per_session - batched - (k - 1) as f64 * m.migration_setup).abs() < 1e-12,
            "batched={batched} per_session={per_session}"
        );
        assert!(batched < per_session);
        // Inside a batch the crossover drops: 4 blocks recompute when
        // shipped alone (setup dominates) but ride along once the batch
        // has paid the setup.
        assert!(!m.migration_wins(4));
        assert!(m.batch_migration_member_wins(4));
        assert!(m.batch_migration_member_wins(64));
        assert!(!m.batch_migration_member_wins(0), "empty chain never ships");
    }

    #[test]
    fn adapter_load_time_zero_by_default_and_costed_when_configured() {
        // Default config: bw 0 → instantaneous, the PR-3 contract.
        let m = model("granite-8b");
        assert_eq!(m.adapter_load_time(32), 0.0);
        assert_eq!(m.adapter_promote_time(32), 0.0);
        // Costed config: setup + linear transfer; promotion skips setup.
        let mut cfg = presets::granite_8b();
        cfg.cache.adapter_load_bw = 25.0e9;
        cfg.cache.adapter_load_setup = 2.0e-3;
        let m = CostModel::new(&cfg);
        let t8 = m.adapter_load_time(8);
        let t32 = m.adapter_load_time(32);
        assert!(t8 > 2.0e-3, "setup is always paid on a cold load");
        assert!(t32 > t8, "transfer is monotone in block count");
        // Linear slope: the marginal block costs kv_bytes*block_size/bw.
        let per_block = 163840.0 * 16.0 / 25.0e9;
        assert!((t32 - t8 - 24.0 * per_block).abs() < 1e-12);
        // Promotion = the same slope with no setup: strictly cheaper.
        assert!((m.adapter_promote_time(32) - 32.0 * per_block).abs() < 1e-12);
        assert!(m.adapter_promote_time(32) < m.adapter_load_time(32));
    }
}
