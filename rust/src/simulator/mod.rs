//! Discrete-event H100 simulator: the [`SimExecutor`].
//!
//! Substitution per DESIGN.md §7 — we have no H100s; the simulator stands
//! in for the GPU workers while the *entire L3 coordinator* (scheduler,
//! block manager, base-aligned prefix cache, masks) runs for real. The
//! executor derives a [`costmodel::StepWork`] summary from each scheduled
//! batch and advances the virtual clock by the modeled duration.
//!
//! Generated token values are synthetic (deterministic per request) —
//! paper §4.1: "all low-rank adapters and all inputs were generated
//! randomly, as the values of these do not affect inference speed."

pub mod costmodel;

use crate::util::fxmap::FxHashMap;

use crate::config::EngineConfig;
use crate::engine::{BatchMask, Executor, StepResult};
use crate::kvcache::manager::KvCacheManager;
use crate::request::{Request, RequestId};
use crate::scheduler::ScheduledStep;

pub use costmodel::{CostModel, StepWork};

pub struct SimExecutor {
    model: CostModel,
    /// Reserved vocab top (so synthetic tokens never collide with
    /// invocation sequences).
    vocab_safe: u32,
    /// Cumulative modeled GPU-busy seconds (utilization accounting).
    busy_time: f64,
    steps: u64,
}

impl SimExecutor {
    pub fn new(cfg: &EngineConfig) -> Self {
        SimExecutor {
            model: CostModel::new(cfg),
            vocab_safe: cfg.model.vocab_size.saturating_sub(64).max(1),
            busy_time: 0.0,
            steps: 0,
        }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.model
    }

    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Summarize a scheduled batch into cost-model work terms.
    fn work_of(
        &self,
        step: &ScheduledStep,
        reqs: &FxHashMap<RequestId, Request>,
        mask: &BatchMask,
    ) -> StepWork {
        let mut w = StepWork { new_blocks: step.new_blocks, ..Default::default() };
        for s in &step.seqs {
            if s.is_decode {
                w.decode_seqs += 1;
                w.decode_ctx_tokens += (s.chunk_start + 1) as f64;
            } else {
                w.prefill_tokens += s.chunk_len;
                // Chunk [start, start+c): token i attends to (start+i+1)
                // positions => c·start + c(c+1)/2.
                let c = s.chunk_len as f64;
                w.prefill_ctx_tokens += c * s.chunk_start as f64 + c * (c + 1.0) / 2.0;
            }
        }
        // Adapted decode tokens: post-activation positions in the mask.
        for (id, off, len) in &mask.spans {
            let r = &reqs[id];
            if r.target.adapter().is_some() {
                w.adapted_tokens += mask.mask_pre[*off..*off + *len]
                    .iter()
                    .filter(|&&pre| !pre)
                    .count();
            }
        }
        w
    }
}

impl Executor for SimExecutor {
    fn execute(
        &mut self,
        step: &ScheduledStep,
        reqs: &FxHashMap<RequestId, Request>,
        _kv: &KvCacheManager,
        mask: &BatchMask,
    ) -> StepResult {
        let work = self.work_of(step, reqs, mask);
        let elapsed = self.model.step_time(&work);
        self.busy_time += elapsed;
        self.steps += 1;

        // Deterministic synthetic token per (request, position).
        let sampled = step
            .seqs
            .iter()
            .filter(|s| s.produces_token)
            .map(|s| {
                let r = &reqs[&s.id];
                let tok = ((s.id.0)
                    .wrapping_mul(0x9E37_79B9)
                    .wrapping_add(r.output_tokens.len() as u64 * 31)
                    % self.vocab_safe as u64) as u32;
                (s.id, tok)
            })
            .collect();

        StepResult { elapsed, sampled }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::engine::Engine;
    use crate::request::{ModelTarget, SamplingParams};

    fn engine(preset: &str) -> Engine<SimExecutor> {
        let cfg = presets::by_name(preset).unwrap();
        let reg = crate::adapter::AdapterRegistry::tiny_default(3, cfg.model.vocab_size, 4);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    }

    #[test]
    fn sim_engine_runs_requests_in_virtual_time() {
        let mut e = engine("granite-8b");
        let id = e
            .submit(
                ModelTarget::Base,
                (0..1024).collect(),
                SamplingParams { max_new_tokens: 16, ..Default::default() },
            )
            .unwrap();
        let out = e.run_to_completion(id);
        assert!(out.timeline.e2e() > 0.0);
        assert!(out.timeline.prefill_time() > 0.0);
        assert!(out.timeline.decode_time() > 0.0);
        // 1k prefill on 8B/H100 is on the order of tens of ms, not seconds.
        assert!(out.timeline.prefill_time() < 1.0, "{:?}", out.timeline);
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let run = || {
            let mut e = engine("granite-8b");
            let id = e
                .submit(
                    ModelTarget::Base,
                    (0..512).collect(),
                    SamplingParams { max_new_tokens: 32, ..Default::default() },
                )
                .unwrap();
            let out = e.run_to_completion(id);
            (out.output_tokens.clone(), out.timeline.e2e())
        };
        let (t1, e1) = run();
        let (t2, e2) = run();
        assert_eq!(t1, t2);
        assert_eq!(e1, e2);
    }

    #[test]
    fn alora_eval_much_faster_than_lora_eval() {
        // The paper's headline mechanism at engine scale: evaluation after
        // a long base turn — aLoRA hits the prefix cache, LoRA re-prefills.
        let prompt: Vec<u32> = (0..8192).collect();
        let mut e = engine("granite-8b");
        let base = e
            .submit(
                ModelTarget::Base,
                prompt.clone(),
                SamplingParams { max_new_tokens: 256, ..Default::default() },
            )
            .unwrap();
        let base_out = e.run_to_completion(base);

        // aLoRA eval (registry tiny_default invocation tokens use vocab top)
        let mut ev_alora = prompt.clone();
        ev_alora.extend(base_out.output_tokens.iter());
        let vocab = 49_155u32;
        ev_alora.extend([vocab - 4, vocab - 3, vocab - 2, vocab - 1]);
        let al = e
            .submit(
                ModelTarget::Adapter(crate::adapter::AdapterId(0)),
                ev_alora.clone(),
                SamplingParams { max_new_tokens: 16, ..Default::default() },
            )
            .unwrap();
        let al_out = e.run_to_completion(al);
        assert!(al_out.num_cached_tokens > 8000, "cache hit expected");

        // LoRA baseline: same engine but feature off.
        let mut cfg = presets::granite_8b();
        cfg.cache.base_aligned_hashing = false;
        let reg = crate::adapter::AdapterRegistry::tiny_default(3, cfg.model.vocab_size, 4);
        let exec = SimExecutor::new(&cfg);
        let mut e2 = Engine::with_registry(cfg, reg, exec);
        let b2 = e2
            .submit(
                ModelTarget::Base,
                prompt.clone(),
                SamplingParams { max_new_tokens: 256, ..Default::default() },
            )
            .unwrap();
        let b2_out = e2.run_to_completion(b2);
        let mut ev2 = prompt.clone();
        ev2.extend(b2_out.output_tokens.iter());
        ev2.extend([vocab - 4, vocab - 3, vocab - 2, vocab - 1]);
        let lr = e2
            .submit(
                ModelTarget::Adapter(crate::adapter::AdapterId(0)),
                ev2,
                SamplingParams { max_new_tokens: 16, ..Default::default() },
            )
            .unwrap();
        let lr_out = e2.run_to_completion(lr);
        assert_eq!(lr_out.num_cached_tokens, 0);

        let speedup = lr_out.timeline.e2e() / al_out.timeline.e2e();
        assert!(speedup > 3.0, "aLoRA should win clearly, got {speedup:.1}x");
    }

    #[test]
    fn busy_time_accumulates() {
        let mut e = engine("granite-8b");
        let id = e
            .submit(ModelTarget::Base, (0..256).collect(), SamplingParams::default())
            .unwrap();
        e.run_to_completion(id);
        assert!(e.executor().busy_time() > 0.0);
        assert!(e.executor().steps() > 0);
    }
}
