//! Cluster scaling: the paper's Figure-9 story lifted to the fleet level.
//!
//! Inside one engine, base-aligned hashing makes adapter follow-ups reuse
//! the base model's KV (Figure 9). Across N replicas that reuse only
//! survives if the router sends each follow-up where its prefix lives.
//! This figure runs the same multi-turn multi-adapter Poisson workload —
//! per-replica arrival rate held constant while the fleet grows — under
//! `PrefixAffinity` and `RoundRobin` routing, and reports aggregate
//! throughput (total tokens / fleet makespan) and fleet-wide prefix
//! hit-rate per (replicas, policy) point. The headline shape: affinity
//! holds the single-engine hit-rate roughly flat as replicas grow, while
//! round-robin's collapses toward `1/N` of it — and the lost reuse shows
//! up as lost aggregate throughput.

use crate::adapter::AdapterId;
use crate::cluster::{Cluster, RoutePolicy};
use crate::pipeline::{self, PipelineKind, PipelineSpec};
use crate::simulator::SimExecutor;

use super::Table;

fn replica_counts(quick: bool) -> Vec<usize> {
    if quick {
        vec![1, 2, 4]
    } else {
        vec![1, 2, 4, 8]
    }
}

const N_ADAPTERS: u32 = 3;

fn mk_cluster(n: usize, policy: RoutePolicy) -> Cluster<SimExecutor> {
    Cluster::from_factory(n, policy, |_| super::make_engine("granite-8b", true, N_ADAPTERS))
        .expect("cluster construction")
}

fn spec() -> PipelineSpec {
    // Multi-turn multi-adapter conversation: base draft → 3 adapter evals
    // → consolidated base call (paper §4.4.1 shape).
    PipelineSpec {
        kind: PipelineKind::MultiAdapter,
        prompt_len: 1024,
        base_gen: 64,
        eval_gen: 16,
        adapters: (0..N_ADAPTERS).map(AdapterId).collect(),
        base2_gen: 16,
        priority_continuations: false,
    }
}

/// One (replicas, policy) measurement.
pub fn run_point(
    replicas: usize,
    policy: RoutePolicy,
    conversations_per_replica: usize,
    rate_per_replica: f64,
) -> (f64, f64, Cluster<SimExecutor>) {
    let mut c = mk_cluster(replicas, policy);
    let n = conversations_per_replica * replicas;
    let rate = rate_per_replica * replicas as f64;
    let r = pipeline::run_poisson(&mut c, &spec(), n, rate, 42);
    let throughput = if r.makespan > 0.0 {
        c.total_tokens_processed() as f64 / r.makespan
    } else {
        0.0
    };
    (throughput, c.aggregate_hit_rate(), c)
}

pub fn run(quick: bool) -> Table {
    let per_replica = if quick { 10 } else { 40 };
    let rate = 4.0;
    let mut t = Table::new(
        "cluster_scaling",
        &format!(
            "aggregate throughput & prefix hit-rate vs replicas, \
             affinity vs round-robin ({per_replica} conv/replica @ {rate}/s/replica)"
        ),
        &[
            "replicas",
            "policy",
            "agg_tok_s",
            "prefix_hit_rate",
            "e2e_mean_s",
            "affinity_hits",
            "fallbacks",
            "imbalance",
        ],
    );
    for &k in &replica_counts(quick) {
        for policy in [RoutePolicy::PrefixAffinity, RoutePolicy::RoundRobin] {
            let (tput, hit, c) = run_point(k, policy, per_replica, rate);
            let e2e_mean = c.aggregate_metrics().all.mean("e2e");
            let stats = &c.router().stats;
            t.push(
                &[k.to_string(), policy.name().to_string()],
                &[
                    tput,
                    hit,
                    e2e_mean,
                    stats.affinity_hits as f64,
                    stats.affinity_fallbacks as f64,
                    stats.imbalance(),
                ],
            );
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineDriver;

    #[test]
    fn affinity_preserves_hit_rate_across_scale_out() {
        let (_, hit_aff, c) = run_point(2, RoutePolicy::PrefixAffinity, 8, 4.0);
        let (_, hit_rr, _) = run_point(2, RoutePolicy::RoundRobin, 8, 4.0);
        assert!(
            hit_aff > hit_rr,
            "affinity {hit_aff:.3} must beat round-robin {hit_rr:.3}"
        );
        // Follow-up stages (4 per conversation) found a warm replica.
        assert!(c.router().stats.affinity_hits > 0);
        assert!(!c.has_work(), "workload drained");
    }

    #[test]
    fn table_shape() {
        let t = run(true);
        assert_eq!(t.rows.len(), 6); // 3 replica counts × 2 policies
        for v in t.col("agg_tok_s") {
            assert!(v > 0.0);
        }
        for v in t.col("prefix_hit_rate") {
            assert!((0.0..=1.0).contains(&v));
        }
        for v in t.col("e2e_mean_s") {
            assert!(v > 0.0);
        }
    }
}
