//! Figure 7: token-level throughput of the evaluation step at 65k prompt,
//! batch chosen to fill the KV cache.

use crate::pipeline::PipelineSpec;

use super::{run_sync_pair, Table};

pub fn run() -> Table {
    let mut t = Table::new(
        "fig7",
        "eval-step token throughput @65k prompt (batch fills KV)",
        &["model", "variant", "throughput(tok/s)", "e2e(s)"],
    );
    for model in ["granite-8b", "llama-70b", "mistral-large-2"] {
        let spec = PipelineSpec::base_adapter(65536, 256, 16);
        let cfg = crate::config::presets::by_name(model).unwrap();
        let batch = crate::pipeline::workload::batch_size_for(&cfg, spec.max_total_len());
        let pair = run_sync_pair(model, &spec, batch, 42);
        for (name, r) in [("aLoRA", &pair.alora), ("LoRA", &pair.lora)] {
            let evals = r.eval_latencies();
            // Table-2 throughput: tokens processed / E2E. The eval step
            // processes (prompt + gen + inv) input + 16 output per request.
            let tokens_per_req = (spec.prompt_len
                + spec.base_gen as usize
                + crate::pipeline::workload::INVOCATION_LEN as usize
                + spec.eval_gen as usize) as f64;
            let e2e = evals.mean("e2e");
            t.push(
                &[model.to_string(), name.to_string()],
                &[tokens_per_req / e2e, e2e],
            );
        }
    }
    t
}

#[cfg(test)]
mod tests {
    #[test]
    #[ignore = "65k sweep is slow in debug; covered by cargo bench --bench bench_fig7"]
    fn fig7_alora_throughput_wins() {
        let t = super::run();
        let thr = t.col("throughput(tok/s)");
        for pair in thr.chunks(2) {
            assert!(pair[0] > pair[1], "aLoRA throughput must exceed LoRA");
        }
    }
}
