//! Figures 13–14 (Appendix E): asynchronous base-adapter pipeline, full
//! base+eval step — aggregate metrics (E2E / TTFT / inference, Fig 13)
//! and stage breakdown (queue / prefill / decode, Fig 14) vs arrival rate.
//!
//! Unlike Figure 8 (eval step only), these cover the ENTIRE conversation
//! (base call + evaluation), matching the appendix's "entire base +
//! evaluation step" framing.

use crate::metrics::StageLatencies;
use crate::pipeline::PipelineSpec;

use super::{run_poisson_pair, Table};

fn all_latencies(r: &crate::pipeline::PipelineResult) -> StageLatencies {
    r.stage_latencies(|_| true)
}

pub fn run(quick: bool) -> Vec<Table> {
    let n = if quick { 100 } else { 500 };
    let rates = super::fig8::rates(quick);
    let mut t13 = Table::new(
        "fig13",
        &format!("async base+eval: E2E / TTFT / inference vs rate (n={n})"),
        &["rate(req/s)", "variant", "e2e(s)", "ttft(s)", "inference(s)"],
    );
    let mut t14 = Table::new(
        "fig14",
        &format!("async base+eval: queue / prefill / decode vs rate (n={n})"),
        &["rate(req/s)", "variant", "queue(s)", "prefill(s)", "decode(s)"],
    );
    let spec = PipelineSpec::base_adapter(256, 256, 16);
    for &rate in &rates {
        let pair = run_poisson_pair("granite-8b", &spec, n, rate, 42);
        for (name, r) in [("aLoRA", &pair.alora), ("LoRA", &pair.lora)] {
            let s = all_latencies(r);
            t13.push(
                &[format!("{rate}"), name.to_string()],
                &[s.mean("e2e"), s.mean("ttft"), s.mean("inference")],
            );
            t14.push(
                &[format!("{rate}"), name.to_string()],
                &[s.mean("queue"), s.mean("prefill"), s.mean("decode")],
            );
        }
    }
    vec![t13, t14]
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig13_14_full_step_alora_wins_at_load() {
        let tables = super::run(true);
        let e2e = tables[0].col("e2e(s)");
        // at the highest rate (last aLoRA/LoRA pair) aLoRA must win
        let n = e2e.len();
        assert!(e2e[n - 2] < e2e[n - 1], "{e2e:?}");
        let q = tables[1].col("queue(s)");
        assert!(q[n - 2] <= q[n - 1] + 1e-9, "queue should favor aLoRA: {q:?}");
    }
}
