//! Migration: the last full-prefix recompute, killed (or deliberately
//! kept — DESIGN.md §18).
//!
//! Two sweeps, one story: what does moving a conversation's KV cost
//! versus rebuilding it?
//!
//! Part 1 (`failover` rows): a 2-replica fleet serves one sticky
//! conversation per prefix length L; its home replica is killed after
//! the first turn. With `prefix_migration` off, the victim's next turn
//! re-prefills the whole chain cold on the survivor. With it on, the
//! repair ships the leased blocks at the cost model's transfer rate
//! (`migration_bw`, plus `migration_setup`) and charges the time on the
//! destination clock — the next-turn TTFT is the figure's y-axis. Short
//! chains sit below the transfer crossover, so the cost model declines
//! and both arms are bit-identical; long chains migrate and win.
//!
//! Part 2 (`fork` rows): fan a parent with a warm prefix out to K
//! children via `SessionManager::fork` versus opening K independent
//! conversations with the same history length. Forked children pin the
//! parent's blocks (zero new prefill blocks) and their first turns ride
//! the shared prefix warm; independent sessions pay K full prefills.

use crate::cluster::{Cluster, RoutePolicy};
use crate::config::{presets, EngineConfig};
use crate::engine::Engine;
use crate::pipeline::workload;
use crate::request::ModelTarget;
use crate::session::SessionManager;
use crate::simulator::SimExecutor;

use super::Table;

pub const REPLICAS: usize = 2;

/// One prefix-length point of the failover sweep.
pub struct MigratePoint {
    pub prefix_tokens: usize,
    /// Victim's next-turn TTFT with migration on / off.
    pub ttft_migrate: f64,
    pub ttft_recompute: f64,
    /// Blocks the migrate arm actually shipped (0 = cost model declined
    /// and fell back to recompute).
    pub migrated_blocks: u64,
}

/// One fan-out point of the fork sweep.
pub struct ForkPoint {
    pub k: usize,
    /// Mean first-turn TTFT of the K children / K independent sessions.
    pub ttft_forked: f64,
    pub ttft_independent: f64,
    /// New KV blocks allocated to serve the K branches.
    pub blocks_forked: u64,
    pub blocks_independent: u64,
}

/// The measured curves, exposed for the acceptance assertions.
pub struct MigrationCurve {
    pub table: Table,
    pub failover: Vec<MigratePoint>,
    pub fork: Vec<ForkPoint>,
}

fn engine(migrate: bool) -> Engine<SimExecutor> {
    let mut cfg: EngineConfig = presets::by_name("granite-8b").expect("preset");
    cfg.cache.base_aligned_hashing = true;
    cfg.cache.prefix_migration = migrate;
    let reg = workload::build_registry(2, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

/// Kill-and-next-turn for one prefix length on one arm: returns the
/// victim conversation's post-failover TTFT, its cached tokens, and the
/// blocks migrated.
fn failover_arm(prefix: usize, migrate: bool) -> (f64, usize, u64) {
    let mut c: Cluster<SimExecutor> =
        Cluster::from_factory(REPLICAS, RoutePolicy::PrefixAffinity, |_| engine(migrate))
            .expect("cluster construction");
    let mgr = SessionManager::new();
    let sid = mgr.create(0);
    let base = 10_000u32;
    mgr.run_turn(&mut c, sid, ModelTarget::Base, (base..base + prefix as u32).collect(), 16, true)
        .expect("first turn");
    let home = (0..REPLICAS)
        .find(|&i| c.replica(i).leased_blocks() > 0)
        .expect("lease pinned on the home replica");
    let report = c.fail_replica(home).expect("failover");
    mgr.repair_after_failover(&mut c, &report);
    let rec = mgr
        .run_turn(&mut c, sid, ModelTarget::Base, vec![77; 32], 16, true)
        .expect("post-failover turn");
    (rec.ttft_s, rec.cached_tokens, c.router().stats.migrated_blocks)
}

/// Fork-vs-independent for one fan-out K: (mean TTFT forked, blocks
/// forked, mean TTFT independent, blocks independent).
fn fork_arm(k: usize, history: usize) -> ForkPoint {
    // Forked: one parent prefill, K children riding it.
    let mut e = engine(false);
    let mgr = SessionManager::new();
    let parent = mgr.create(0);
    mgr.run_turn(&mut e, parent, ModelTarget::Base, (0..history as u32).collect(), 16, true)
        .expect("parent turn");
    let before = e.metrics.blocks_allocated;
    let kids = mgr.fork(&mut e, parent, k, &[]).expect("fork");
    let mut ttft_forked = 0.0;
    for (i, kid) in kids.iter().enumerate() {
        let rec = mgr
            .run_turn(&mut e, *kid, ModelTarget::Base, vec![900 + i as u32; 16], 8, true)
            .expect("child turn");
        ttft_forked += rec.ttft_s;
    }
    let blocks_forked = e.metrics.blocks_allocated - before;

    // Independent: K sessions, each with its own (distinct) history of
    // the same length plus the same 16-token tail — K full prefills.
    let mut e2 = engine(false);
    let mgr2 = SessionManager::new();
    let before2 = e2.metrics.blocks_allocated;
    let mut ttft_independent = 0.0;
    for i in 0..k {
        let sid = mgr2.create(0);
        let base = (i as u32 + 1) * 100_000;
        let mut prompt: Vec<u32> = (base..base + history as u32).collect();
        prompt.extend(std::iter::repeat(900 + i as u32).take(16));
        let rec = mgr2
            .run_turn(&mut e2, sid, ModelTarget::Base, prompt, 8, true)
            .expect("independent turn");
        ttft_independent += rec.ttft_s;
    }
    let blocks_independent = e2.metrics.blocks_allocated - before2;

    ForkPoint {
        k,
        ttft_forked: ttft_forked / k as f64,
        ttft_independent: ttft_independent / k as f64,
        blocks_forked,
        blocks_independent,
    }
}

pub fn run_curve(quick: bool) -> MigrationCurve {
    // 128 sits below the transfer crossover (the cost model declines and
    // recomputes); everything above it migrates.
    let lens: Vec<usize> =
        if quick { vec![128, 2048] } else { vec![128, 256, 512, 1024, 2048, 4096, 8192] };
    let ks: Vec<usize> = if quick { vec![2, 4] } else { vec![2, 4, 8, 16] };

    let mut table = Table::new(
        "migration",
        &format!(
            "cross-replica prefix migration vs recompute after failover \
             ({REPLICAS} replicas), and K-way session forking vs K \
             independent sessions"
        ),
        &[
            "case",
            "prefix_tokens",
            "k",
            "ttft_migrate_s",
            "ttft_recompute_s",
            "migrated_blocks",
            "new_blocks_forked",
            "new_blocks_independent",
        ],
    );

    let mut failover = Vec::with_capacity(lens.len());
    for &prefix in &lens {
        let (ttft_migrate, _cached_m, migrated_blocks) = failover_arm(prefix, true);
        let (ttft_recompute, _cached_r, _) = failover_arm(prefix, false);
        table.push(
            &["failover".into()],
            &[
                prefix as f64,
                0.0,
                ttft_migrate,
                ttft_recompute,
                migrated_blocks as f64,
                0.0,
                0.0,
            ],
        );
        failover.push(MigratePoint { prefix_tokens: prefix, ttft_migrate, ttft_recompute, migrated_blocks });
    }

    let history = 1024;
    let mut fork = Vec::with_capacity(ks.len());
    for &k in &ks {
        let p = fork_arm(k, history);
        table.push(
            &["fork".into()],
            &[
                history as f64,
                k as f64,
                p.ttft_forked,
                p.ttft_independent,
                0.0,
                p.blocks_forked as f64,
                p.blocks_independent as f64,
            ],
        );
        fork.push(p);
    }

    MigrationCurve { table, failover, fork }
}

pub fn run(quick: bool) -> Table {
    run_curve(quick).table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_prefixes_migrate_and_win_short_ones_recompute_identically() {
        let curve = run_curve(true);
        let short = &curve.failover[0];
        let long = curve.failover.last().unwrap();
        // Below the crossover the cost model declines: zero blocks moved
        // and the recompute arm is reproduced exactly.
        assert_eq!(short.migrated_blocks, 0, "short prefix must not migrate");
        assert_eq!(
            short.ttft_migrate, short.ttft_recompute,
            "declined migration must be bit-identical to recompute"
        );
        // Above it the transfer is strictly cheaper than the re-prefill.
        assert!(long.migrated_blocks > 0, "long prefix must migrate");
        assert!(
            long.ttft_migrate < long.ttft_recompute,
            "migration lost to recompute at {} tokens: {:.4}s vs {:.4}s",
            long.prefix_tokens,
            long.ttft_migrate,
            long.ttft_recompute
        );
    }

    #[test]
    fn forking_beats_independent_sessions_on_blocks_and_ttft() {
        let curve = run_curve(true);
        for p in &curve.fork {
            // Children allocate only their own divergent tails; the
            // shared prefix is pinned, not re-prefilled. Independent
            // sessions pay ~K × the full history in fresh blocks.
            assert!(
                p.blocks_forked < p.blocks_independent / 2,
                "k={}: forked {} vs independent {} blocks",
                p.k,
                p.blocks_forked,
                p.blocks_independent
            );
            assert!(
                p.ttft_forked < p.ttft_independent,
                "k={}: warm fork TTFT {:.4}s vs cold {:.4}s",
                p.k,
                p.ttft_forked,
                p.ttft_independent
            );
        }
    }

    #[test]
    fn table_shape() {
        let t = run(true);
        assert_eq!(t.rows.len(), 4); // 2 prefix points + 2 fan-outs
        for v in t.col("ttft_migrate_s") {
            assert!(v > 0.0);
        }
    }
}
