//! Figure 6: synchronous base-adapter pipeline, prompt-length sweep.
//!
//! Paper: evaluation-step latencies (E2E / queue / prefill / decode) for
//! LoRA vs aLoRA across prompt lengths and all three models; speedups
//! scale with prompt length and model size up to 58× E2E / 45× prefill /
//! 21× decode. Batch size is fixed by the paper's rule at the *largest*
//! prompt length of the sweep (fairness — Appendix F / Figure 15 shows
//! what happens otherwise).

use crate::metrics::STAGES;
use crate::pipeline::PipelineSpec;

use super::{run_sync_pair, Table};

pub const BASE_GEN: u32 = 256;
pub const EVAL_GEN: u32 = 16;

pub fn models(quick: bool) -> Vec<&'static str> {
    if quick {
        vec!["granite-8b"]
    } else {
        vec!["granite-8b", "llama-70b", "mistral-large-2"]
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let lens = super::prompt_sweep(quick);
    let max_len_spec = PipelineSpec::base_adapter(*lens.last().unwrap(), BASE_GEN, EVAL_GEN);
    let mut tables = Vec::new();

    for model in models(quick) {
        let cfg = crate::config::presets::by_name(model).unwrap();
        // Fixed batch: the paper sizes it for the LARGEST prompt length.
        let batch = crate::pipeline::workload::batch_size_for(&cfg, max_len_spec.max_total_len());
        let mut t = Table::new(
            "fig6",
            &format!("base-adapter eval latencies vs prompt length — {model} (batch {batch})"),
            &[
                "prompt_len",
                "variant",
                "e2e(s)",
                "queue(s)",
                "prefill(s)",
                "decode(s)",
                "hit_rate",
            ],
        );
        let mut speedups = Table::new(
            "fig6-speedup",
            &format!("aLoRA speedup over LoRA — {model}"),
            &["prompt_len", "e2e_x", "queue_x", "prefill_x", "decode_x"],
        );
        for &plen in &lens {
            let spec = PipelineSpec::base_adapter(plen, BASE_GEN, EVAL_GEN);
            let pair = run_sync_pair(model, &spec, batch, 42);
            let a = pair.alora.eval_latencies();
            let l = pair.lora.eval_latencies();
            for (name, r, hit) in [
                ("aLoRA", &a, pair.alora.eval_hit_rate()),
                ("LoRA", &l, pair.lora.eval_hit_rate()),
            ] {
                t.push(
                    &[plen.to_string(), name.to_string()],
                    &[
                        r.mean("e2e"),
                        r.mean("queue"),
                        r.mean("prefill"),
                        r.mean("decode"),
                        hit,
                    ],
                );
            }
            let sx = |stage: &str| {
                let num = l.mean(stage);
                let den = a.mean(stage);
                if den <= 0.0 { f64::NAN } else { num / den }
            };
            speedups.push(
                &[plen.to_string()],
                &[sx("e2e"), sx("queue"), sx("prefill"), sx("decode")],
            );
        }
        let _ = STAGES; // (stage list documented in metrics)
        tables.push(t);
        tables.push(speedups);
    }
    tables
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig6_quick_shape() {
        let tables = super::run(true);
        assert_eq!(tables.len(), 2);
        let sp = &tables[1];
        let e2e: Vec<f64> = sp.col("e2e_x");
        // speedup > 1 everywhere and grows with prompt length
        assert!(e2e.iter().all(|&x| x > 1.0), "{e2e:?}");
        assert!(e2e.last().unwrap() > e2e.first().unwrap());
        // prefill savings present at every length. (The 45×-style growth
        // only appears once prompts exceed the chunked-prefill budget —
        // quick mode tops out at 4096 < 8192; the full sweep in
        // `cargo bench --bench bench_fig6` covers 65k.)
        let pf: Vec<f64> = sp.col("prefill_x");
        assert!(pf.iter().all(|&x| x > 3.0), "{pf:?}");
    }
}
