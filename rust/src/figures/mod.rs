//! Reproduction harness: one function per paper table/figure.
//!
//! Every `figN()` runs the corresponding workload on the discrete-event
//! simulator — the identical L3 code path as production, with the H100
//! cost model supplying step durations (DESIGN.md §7) — for both the
//! aLoRA engine (base-aligned hashing ON) and the standard-LoRA baseline
//! (OFF), and returns the paper's rows. Absolute seconds are this
//! testbed's; the *shape* (who wins, scaling, crossovers) is the
//! reproduction target and is asserted in rust/tests/figures.rs.
//!
//! Figure index (DESIGN.md §4): T1 configs · F6 prompt-length sweep ·
//! F7 throughput@65k · F8 async rates · F9 rate×length grid · F10
//! gen-length + multi-adapter · F11 adapter-base · F12 TTFT/inference ·
//! F13/14 async full-step breakdowns · F15 KV-filling batch sizes ·
//! cluster_scaling (ours, beyond the paper): fleet-level hit-rate and
//! throughput vs replica count under affinity vs round-robin routing ·
//! adapter_memory (ours): adapter-count × memory-budget sweep of the
//! unified KV + adapter-weight budget vs the always-resident baseline ·
//! failover (ours): kill one of four replicas mid-burst — per-round
//! hit-rate dip and re-warm, zero lost requests · migration (ours):
//! migrate-vs-recompute next-turn TTFT across prefix lengths after a
//! home-replica kill, plus K-way fork fan-out vs K independent sessions ·
//! selfdriving (ours): the failure detector declaring a silenced
//! replica's failover unattended, and the autoscaler riding a diurnal
//! load cycle up and back down with zero lost requests ·
//! adapter_tiering (ours): time-costed host↔device adapter transfers —
//! drop vs host-tier demotion vs prefetch, plus heterogeneous vs
//! homogeneous fleet packing at equal total budget.

pub mod ablations;
pub mod adapter_memory;
pub mod adapter_tiering;
pub mod cluster_scaling;
pub mod concurrency;
pub mod failover;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13_14;
pub mod fig15;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod migration;
pub mod scale;
pub mod selfdriving;
pub mod table1;
pub mod table2;

use crate::adapter::AdapterId;
use crate::config::{presets, EngineConfig};
use crate::engine::Engine;
use crate::pipeline::{self, workload, PipelineResult, PipelineSpec};
use crate::simulator::SimExecutor;

/// A rendered result table (also machine-readable: `data` holds the raw
/// numbers keyed like the header row).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Raw numeric cells: (row index, header) -> value, for assertions.
    pub data: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> Self {
        Table {
            id: id.into(),
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            data: Vec::new(),
        }
    }

    /// Push a row: label columns first, then numeric columns.
    pub fn push(&mut self, labels: &[String], nums: &[f64]) {
        let mut row: Vec<String> = labels.to_vec();
        for &x in nums {
            row.push(fmt_value(x));
        }
        self.rows.push(row);
        self.data.push(nums.to_vec());
    }

    /// Column value by header name (numeric columns only).
    pub fn col(&self, header: &str) -> Vec<f64> {
        let label_cols = self.headers.len() - self.data.first().map(|d| d.len()).unwrap_or(0);
        let idx = self
            .headers
            .iter()
            .position(|h| h == header)
            .unwrap_or_else(|| panic!("no column `{header}` in {}", self.id));
        assert!(idx >= label_cols, "`{header}` is a label column");
        self.data.iter().map(|d| d[idx - label_cols]).collect()
    }

    pub fn print(&self) {
        println!("\n## {} — {}", self.id, self.title);
        let hdrs: Vec<&str> = self.headers.iter().map(String::as_str).collect();
        crate::util::bench::print_table(&hdrs, &self.rows);
    }

    /// CSV rendering (rendered cells, header row first).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Machine-readable JSON: {id, title, headers, rows (rendered), data (raw)}.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("title", Json::str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::str(c.clone())).collect()))
                        .collect(),
                ),
            ),
            (
                "data",
                Json::Arr(self.data.iter().map(|d| Json::arr_f64(d)).collect()),
            ),
        ])
    }

    /// Write `<dir>/<id>.csv` and `<dir>/<id>.json`.
    pub fn save(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.csv", self.id)), self.to_csv())?;
        std::fs::write(dir.join(format!("{}.json", self.id)), self.to_json().to_string())?;
        Ok(())
    }
}

fn fmt_value(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else if x.abs() >= 0.001 {
        format!("{:.2}ms", x * 1000.0).replace("ms", "e-3")
    } else {
        format!("{x:.2e}")
    }
}

/// Engine factory for one variant.
pub fn make_engine(cfg_name: &str, alora: bool, n_adapters: u32) -> Engine<SimExecutor> {
    let mut cfg: EngineConfig = presets::by_name(cfg_name).expect("unknown preset");
    cfg.cache.base_aligned_hashing = alora;
    let reg = workload::build_registry(n_adapters, cfg.model.vocab_size, alora);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

/// Run one pipeline spec on both variants (aLoRA ours / LoRA baseline)
/// with the paper's batch rule, same seed.
pub struct VariantPair {
    pub alora: PipelineResult,
    pub lora: PipelineResult,
    pub batch: usize,
}

pub fn run_sync_pair(
    cfg_name: &str,
    spec: &PipelineSpec,
    batch: usize,
    seed: u64,
) -> VariantPair {
    let n_adapters = spec.adapters.len().max(1) as u32;
    let mut ea = make_engine(cfg_name, true, n_adapters);
    let alora = pipeline::run_sync(&mut ea, spec, batch, seed);
    let mut el = make_engine(cfg_name, false, n_adapters);
    let lora = pipeline::run_sync(&mut el, spec, batch, seed);
    VariantPair { alora, lora, batch }
}

pub fn run_poisson_pair(
    cfg_name: &str,
    spec: &PipelineSpec,
    n: usize,
    lambda: f64,
    seed: u64,
) -> VariantPair {
    let n_adapters = spec.adapters.len().max(1) as u32;
    let mut ea = make_engine(cfg_name, true, n_adapters);
    let alora = pipeline::run_poisson(&mut ea, spec, n, lambda, seed);
    let mut el = make_engine(cfg_name, false, n_adapters);
    let lora = pipeline::run_poisson(&mut el, spec, n, lambda, seed);
    VariantPair { alora, lora, batch: 0 }
}

/// Default prompt-length sweep (paper: up to 65k).
pub fn prompt_sweep(quick: bool) -> Vec<usize> {
    if quick {
        vec![128, 1024, 4096]
    } else {
        vec![128, 512, 1024, 4096, 16384, 65536]
    }
}

/// Single adapter id used by single-adapter pipelines.
pub fn a0() -> AdapterId {
    AdapterId(0)
}

/// Run every figure (CLI `figure --id all`); quick mode shrinks sweeps.
pub fn run_all(quick: bool) -> Vec<Table> {
    let mut out = vec![table1::run(), table2::run()];
    out.extend(fig6::run(quick));
    out.push(fig7::run());
    out.push(fig8::run(quick));
    out.push(fig9::run(quick));
    out.extend(fig10::run(quick));
    out.push(fig11::run(quick));
    out.push(fig12::run(quick));
    out.extend(fig13_14::run(quick));
    out.push(fig15::run(quick));
    out.push(cluster_scaling::run(quick));
    out.push(adapter_memory::run(quick));
    out.push(adapter_tiering::run(quick));
    out.push(failover::run(quick));
    out.push(migration::run(quick));
    out.extend(selfdriving::run(quick));
    out
}

/// Look up a figure by id ("table1", "fig6", ... or "all").
pub fn run_by_id(id: &str, quick: bool) -> Vec<Table> {
    match id {
        "all" => run_all(quick),
        "table1" => vec![table1::run()],
        "table2" => vec![table2::run()],
        "fig6" => fig6::run(quick),
        "fig7" => vec![fig7::run()],
        "fig8" => vec![fig8::run(quick)],
        "fig9" => vec![fig9::run(quick)],
        "fig10" => fig10::run(quick),
        "fig11" => vec![fig11::run(quick)],
        "fig12" => vec![fig12::run(quick)],
        "fig13_14" => fig13_14::run(quick),
        "fig15" => vec![fig15::run(quick)],
        "cluster" | "cluster_scaling" => vec![cluster_scaling::run(quick)],
        "adapter_memory" => vec![adapter_memory::run(quick)],
        "adapter_tiering" => vec![adapter_tiering::run(quick)],
        "failover" => vec![failover::run(quick)],
        "migration" => vec![migration::run(quick)],
        "selfdriving" => selfdriving::run(quick),
        "ablations" => ablations::run_all(),
        // Deliberately not part of `all`: the scale and concurrency
        // harnesses are long-running bench-tier figures (like
        // `ablations`), and `concurrency` measures REAL wall-clock.
        "scale" => vec![scale::run(quick)],
        "concurrency" => vec![concurrency::run(quick)],
        other => panic!(
            "unknown figure id `{other}` (try table1, fig6..fig15, cluster, \
             adapter_memory, adapter_tiering, failover, migration, \
             selfdriving, ablations, scale, concurrency, all)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_push_and_col() {
        let mut t = Table::new("t", "test", &["name", "a", "b"]);
        t.push(&["x".into()], &[1.0, 2.0]);
        t.push(&["y".into()], &[3.0, 4.0]);
        assert_eq!(t.col("a"), vec![1.0, 3.0]);
        assert_eq!(t.col("b"), vec![2.0, 4.0]);
        t.print();
    }

    #[test]
    #[should_panic(expected = "no column")]
    fn missing_column_panics() {
        let t = Table::new("t", "test", &["name", "a"]);
        t.col("zzz");
    }
}
