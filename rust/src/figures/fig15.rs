//! Figure 15 (Appendix F): what happens when batch size is chosen to fill
//! the KV cache *per prompt length* instead of being fixed across the
//! sweep — decode time from the huge batches dominates E2E at short
//! prompt lengths, which is why the synchronous trials fix batch size.

use crate::pipeline::PipelineSpec;

use super::{run_sync_pair, Table};

pub fn run(quick: bool) -> Table {
    let lens = super::prompt_sweep(quick);
    let mut t = Table::new(
        "fig15",
        "base-adapter eval with per-length KV-filling batch size",
        &["prompt_len", "batch", "variant", "e2e(s)", "queue(s)", "prefill(s)", "decode(s)"],
    );
    let cfg = crate::config::presets::granite_8b();
    for &plen in &lens {
        let spec = PipelineSpec::base_adapter(plen, 256, 16);
        // Per-length batch (the misleading methodology the appendix warns
        // about): short prompts -> enormous batches -> decode dominated.
        let batch = crate::pipeline::workload::batch_size_for(&cfg, spec.max_total_len());
        let pair = run_sync_pair("granite-8b", &spec, batch, 42);
        for (name, r) in [("aLoRA", &pair.alora.eval_latencies()), ("LoRA", &pair.lora.eval_latencies())] {
            t.push(
                &[plen.to_string(), batch.to_string(), name.to_string()],
                &[r.mean("e2e"), r.mean("queue"), r.mean("prefill"), r.mean("decode")],
            );
        }
    }
    t
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig15_decode_dominates_short_prompts_with_filling_batches() {
        let t = super::run(true);
        let decode = t.col("decode(s)");
        let prefill = t.col("prefill(s)");
        // first row = shortest prompt, aLoRA: decode must dominate prefill
        assert!(
            decode[0] > prefill[0],
            "decode {decode:?} should dominate prefill {prefill:?} at short lengths"
        );
    }
}
