//! Failover: kill one of four replicas mid-burst, watch the fleet's
//! prefix hit-rate dip and re-warm.
//!
//! The cluster-scaling figure shows affinity routing preserving the
//! paper's reuse across scale-out; this one shows it surviving the event
//! production actually brings — a replica failure. A fleet of 4 replicas
//! serves N sticky multi-turn sessions in rounds (every session one delta
//! turn per round, leases pinning each chain between rounds). Mid-burst —
//! turns in flight — replica 1 is failed: its queued work is requeued
//! onto survivors under the same request ids, its leases orphan, and its
//! sessions re-stick. The per-round token hit-rate tells the story: flat
//! and high pre-failure, a dip at the failover round (the victim's
//! conversations re-prefill their chains cold on survivors), then
//! recovery above the dip as the re-stuck sessions re-warm — and zero
//! requests are lost throughout.

use crate::cluster::{Cluster, RoutePolicy};
use crate::engine::EngineDriver;
use crate::request::session::SessionId;
use crate::request::{ModelTarget, RequestId, RequestOutput};
use crate::session::SessionManager;
use crate::simulator::SimExecutor;
use crate::util::fxmap::FxHashMap;

use super::Table;

pub const REPLICAS: usize = 4;
pub const VICTIM: usize = 1;
/// Round whose in-flight burst the failure interrupts.
pub const FAIL_ROUND: usize = 2;

/// The measured curve, exposed for the acceptance assertions.
pub struct FailoverCurve {
    pub table: Table,
    /// Per-round token hit-rate (cached / prompt over the round's turns).
    pub hit_rates: Vec<f64>,
    /// Requests requeued by the failover.
    pub requeued: u64,
    /// Conversations re-stuck through the routing policy (0 when every
    /// victim conversation was mid-turn — their requeued turns re-home
    /// them on completion instead).
    pub resticks: u64,
    /// Leases orphaned by the failure.
    pub orphaned: u64,
    /// Turns completed (every submitted request produced its output).
    pub turns_completed: usize,
    /// Turns submitted across all rounds.
    pub turns_submitted: usize,
}

impl FailoverCurve {
    /// The post-failure dip: the worst round from the failure on.
    pub fn dip(&self) -> f64 {
        self.hit_rates[FAIL_ROUND..]
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
    }

    /// Steady state after re-warming (the last round).
    pub fn recovered(&self) -> f64 {
        *self.hit_rates.last().expect("at least one round")
    }
}

pub fn run_curve(quick: bool) -> FailoverCurve {
    let n_sessions = if quick { 16 } else { 48 };
    let rounds = if quick { 6 } else { 10 };
    let mut c: Cluster<SimExecutor> =
        Cluster::from_factory(REPLICAS, RoutePolicy::PrefixAffinity, |_| {
            super::make_engine("granite-8b", true, 2)
        })
        .expect("cluster construction");
    let mut mgr = SessionManager::new();
    let sessions: Vec<SessionId> = (0..n_sessions).map(|_| mgr.create(0)).collect();

    let mut table = Table::new(
        "failover",
        &format!(
            "per-round fleet hit-rate across a replica failure \
             ({REPLICAS} replicas, {n_sessions} sticky sessions, \
             replica {VICTIM} killed mid-round {FAIL_ROUND})"
        ),
        &[
            "round",
            "phase",
            "hit_rate",
            "ttft_mean_s",
            "requeued",
            "resticks",
            "orphaned_leases",
        ],
    );
    let mut hit_rates = Vec::with_capacity(rounds);
    let (mut completed, mut submitted) = (0usize, 0usize);

    for round in 0..rounds {
        // Every session submits one delta turn (round 0 opens the
        // conversation with a long unique prompt; later rounds extend it).
        let mut pending: Vec<(SessionId, RequestId)> = Vec::with_capacity(sessions.len());
        for (si, &sid) in sessions.iter().enumerate() {
            let base = (si as u32 + 1) * 10_000 + round as u32 * 100;
            let delta: Vec<u32> = if round == 0 {
                (base..base + 256).collect()
            } else {
                (base..base + 32).collect()
            };
            let (_turn, rid) = mgr
                .begin_turn(&mut c, sid, ModelTarget::Base, delta, 16, true)
                .expect("turn submission");
            pending.push((sid, rid));
        }
        submitted += pending.len();

        if round == FAIL_ROUND {
            // Mid-burst: the round's turns are in flight when the replica
            // dies. Its work requeues under the same ids; its sessions'
            // leases orphan and their stickiness clears.
            for _ in 0..3 {
                c.step();
            }
            let report = c.fail_replica(VICTIM).expect("failover");
            assert!(report.rejected.is_empty(), "identical survivors must accept");
            mgr.repair_after_failover(&mut c, &report);
        }

        // Drain the round: every submitted turn must finish somewhere.
        let mut outs: FxHashMap<RequestId, RequestOutput> = FxHashMap::default();
        loop {
            for o in c.take_finished() {
                outs.insert(o.id, o);
            }
            if pending.iter().all(|(_, rid)| outs.contains_key(rid)) {
                break;
            }
            assert!(c.step(), "cluster stalled with turns outstanding");
        }
        let (mut cached, mut prompted, mut ttft_sum) = (0usize, 0usize, 0.0f64);
        for (sid, rid) in &pending {
            let out = outs.remove(rid).expect("drained above");
            let rec = mgr.complete_turn(&mut c, *sid, &out).expect("turn completion");
            cached += rec.cached_tokens;
            prompted += rec.prompt_len;
            ttft_sum += rec.ttft_s;
            completed += 1;
        }
        let hit = cached as f64 / prompted as f64;
        hit_rates.push(hit);
        let phase = match round.cmp(&FAIL_ROUND) {
            std::cmp::Ordering::Less => "pre-failure",
            std::cmp::Ordering::Equal => "failover",
            std::cmp::Ordering::Greater => "recovery",
        };
        let stats = &c.router().stats;
        table.push(
            &[round.to_string(), phase.to_string()],
            &[
                hit,
                ttft_sum / pending.len() as f64,
                stats.requeued_requests as f64,
                stats.resticks as f64,
                stats.orphaned_leases as f64,
            ],
        );
    }

    let stats = &c.router().stats;
    FailoverCurve {
        hit_rates,
        requeued: stats.requeued_requests,
        resticks: stats.resticks,
        orphaned: stats.orphaned_leases,
        turns_completed: completed,
        turns_submitted: submitted,
        table,
    }
}

pub fn run(quick: bool) -> Table {
    run_curve(quick).table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_dips_hit_rate_and_recovery_beats_the_dip() {
        let curve = run_curve(true);
        // Zero lost requests: every turn of every round completed.
        assert_eq!(curve.turns_completed, curve.turns_submitted);
        // The failure actually moved work and orphaned state. (No
        // resticks expected here: every victim conversation was mid-turn,
        // so its own requeued turn re-homed it on completion — the
        // restick path covers parked/drained conversations instead.)
        assert!(curve.requeued > 0, "no in-flight work was requeued");
        assert!(curve.orphaned > 0, "no leases were orphaned");
        assert_eq!(curve.resticks, 0, "mid-turn sessions re-home via requeue");
        // Warm steady state before the failure...
        let pre = curve.hit_rates[FAIL_ROUND - 1];
        assert!(pre > 0.8, "pre-failure steady state not warm: {pre:.3}");
        // ...a real dip at/after the failure...
        let dip = curve.dip();
        assert!(dip < pre, "failure produced no dip: {:?}", curve.hit_rates);
        // ...and the fleet re-warms above the dip (the acceptance bar).
        let rec = curve.recovered();
        assert!(
            rec > dip,
            "hit-rate failed to recover: dip {dip:.3}, final {rec:.3} ({:?})",
            curve.hit_rates
        );
        assert!(rec > 0.8, "recovery did not re-warm: {rec:.3}");
    }

    #[test]
    fn table_shape() {
        let t = run(true);
        assert_eq!(t.rows.len(), 6);
        for v in t.col("hit_rate") {
            assert!((0.0..=1.0).contains(&v));
        }
        assert!(t.col("requeued").last().copied().unwrap() > 0.0);
    }
}
