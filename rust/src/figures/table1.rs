//! Table 1: model and server configurations.

use crate::config::presets;

use super::Table;

pub fn run() -> Table {
    let mut t = Table::new(
        "table1",
        "Model and server configurations (paper Table 1)",
        &["model", "params(B)", "gpus", "gpu_mem(GB)", "max_kv_tokens", "kv_bytes/tok", "blocks"],
    );
    for name in ["granite-8b", "llama-70b", "mistral-large-2"] {
        let cfg = presets::by_name(name).unwrap();
        t.push(
            &[cfg.model.name.clone()],
            &[
                cfg.model.n_params / 1e9,
                cfg.gpu.n_gpus as f64,
                cfg.gpu.n_gpus as f64 * 80.0,
                cfg.cache.max_kv_tokens as f64,
                cfg.model.kv_bytes_per_token(),
                cfg.cache.num_blocks() as f64,
            ],
        );
    }
    t
}

#[cfg(test)]
mod tests {
    #[test]
    fn table1_matches_paper() {
        let t = super::run();
        assert_eq!(t.col("max_kv_tokens"), vec![351104.0, 407984.0, 912688.0]);
        assert_eq!(t.col("gpus"), vec![1.0, 4.0, 8.0]);
    }
}
