//! Adapter-tiering figure (ours, beyond the paper): what *time-costed*
//! two-tier adapter memory buys over the instantaneous model
//! (DESIGN.md §20).
//!
//! Two experiments share one table:
//!
//! 1. **Churn sweep** (single engine): the same adapter-churn workload —
//!    requests cycling over more adapters than the device budget holds —
//!    under four residency configurations:
//!    - `drop` — costed transfers, no host tier: every eviction discards
//!      the weights, every reload pays setup + per-block bandwidth.
//!    - `demote` — host tier on: evictions park weights in host memory,
//!      reloads promote at bandwidth-only cost (no setup).
//!    - `demote+prefetch` — additionally overlaps a queued request's cold
//!      transfer with its queue wait (scheduler phase 3).
//!    - `zero-cost` — the pre-§20 instantaneous baseline (bw = 0): what
//!      the old model claimed the same workload cost.
//!    Headline shape: `drop → demote` cuts reload latency (promotions
//!    replace cold loads; makespan drops by the saved setup times), and
//!    `demote → demote+prefetch` strictly cuts load-stall steps.
//!
//! 2. **Fleet packing** (two replicas, equal TOTAL budget): five 32-block
//!    adapters cannot split evenly over two 96-block replicas — whichever
//!    replica ends with three adapters holds 96 blocks of weights and
//!    zero room for KV, so it thrashes every round. A heterogeneous
//!    136 + 56 split packs 4 + 1 cleanly, and the router's
//!    `free_budget_weight` steers cold adapters toward the headroom.
//!    Headline shape: heterogeneous aggregate residency hit-rate strictly
//!    beats homogeneous at the same total budget.

use crate::adapter::AdapterId;
use crate::cluster::{Cluster, RoutePolicy, RouterConfig};
use crate::config::{presets, EngineConfig, FleetConfig, ReplicaSpec};
use crate::engine::{Engine, EngineDriver};
use crate::pipeline::workload;
use crate::request::{ModelTarget, SamplingParams};
use crate::simulator::SimExecutor;

use super::Table;

/// Engine config for the churn sweep: a 96-block device (two 32-block
/// adapters + KV), costed transfers unless `bw` is 0.
pub fn cfg_for(host_blocks: u64, bw: f64, prefetch: bool) -> EngineConfig {
    let mut cfg = presets::granite_8b();
    cfg.scheduler.max_seq_len = 256;
    cfg.scheduler.max_batch_tokens = 2048;
    cfg.scheduler.max_num_seqs = 8;
    cfg.cache.max_kv_tokens = 96 * cfg.cache.block_size as u64;
    cfg.cache.adapter_paging = true;
    cfg.cache.adapter_load_bw = bw;
    cfg.cache.adapter_load_setup = if bw > 0.0 { 2.0e-3 } else { 0.0 };
    cfg.cache.host_adapter_blocks = host_blocks;
    cfg.cache.adapter_prefetch = prefetch;
    cfg
}

/// PCIe-gen4-ish host→device bandwidth used by the costed arms.
pub const LOAD_BW: f64 = 64e9;

/// One churn-sweep measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnResult {
    pub loads: u64,
    pub evictions: u64,
    pub demotions: u64,
    pub promotions: u64,
    pub host_drops: u64,
    pub prefetches: u64,
    pub stall_steps: u64,
    pub adapter_hit_rate: f64,
    pub ttft_mean: f64,
    pub makespan: f64,
}

/// Run `n_requests` cycling over 3 adapters (96 weight blocks — more than
/// the 96-block device can hold beside KV) on one engine. All requests
/// are submitted up front so transfers can overlap queue waits.
pub fn run_churn(host_blocks: u64, bw: f64, prefetch: bool, n_requests: usize) -> ChurnResult {
    let cfg = cfg_for(host_blocks, bw, prefetch);
    let reg = workload::build_registry(3, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    let mut e = Engine::with_registry(cfg, reg, exec);
    let params = SamplingParams { max_new_tokens: 8, ..Default::default() };
    for k in 0..n_requests {
        let prompt = vec![100 + k as u32; 64];
        e.submit(ModelTarget::Adapter(AdapterId((k % 3) as u32)), prompt, params)
            .unwrap();
    }
    e.run_until_idle();
    let rs = e.residency().stats();
    ChurnResult {
        loads: rs.loads,
        evictions: rs.evictions,
        demotions: rs.demotions,
        promotions: rs.promotions,
        host_drops: rs.host_drops,
        prefetches: rs.prefetches,
        stall_steps: rs.load_stall_steps,
        adapter_hit_rate: rs.hit_rate(),
        ttft_mean: e.metrics.all.mean("ttft"),
        makespan: e.clock(),
    }
}

/// One fleet-packing measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetResult {
    pub aggregate_adapter_hit_rate: f64,
    pub loads: u64,
    pub evictions: u64,
    pub makespan: f64,
}

/// Two replicas at equal TOTAL budget (192 blocks): heterogeneous
/// 136 + 56 vs homogeneous 96 + 96, serving `rounds` round-robin passes
/// over 5 adapters sequentially (placement is then driven purely by
/// residency affinity and free budget, never by queue depth).
pub fn run_fleet(hetero: bool, rounds: usize) -> FleetResult {
    let mut base = presets::granite_8b();
    base.scheduler.max_seq_len = 256;
    base.scheduler.max_batch_tokens = 1024;
    base.scheduler.max_num_seqs = 4;
    base.cache.adapter_paging = true;
    let bs = base.cache.block_size as u64;
    let blocks: [u64; 2] = if hetero { [136, 56] } else { [96, 96] };
    let fleet = FleetConfig {
        replica_specs: blocks
            .iter()
            .map(|&b| ReplicaSpec { max_kv_tokens: b * bs, host_adapter_blocks: 0 })
            .collect(),
        ..FleetConfig::default()
    };
    let rcfg = RouterConfig {
        policy: RoutePolicy::AdapterAffinity,
        free_budget_weight: 1.0,
        ..Default::default()
    };
    let mut c = Cluster::from_specs(2, &base, rcfg, fleet, 2, |_, cfg| {
        let reg = workload::build_registry(5, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    })
    .unwrap();
    let params = SamplingParams { max_new_tokens: 4, ..Default::default() };
    for k in 0..rounds * 5 {
        let prompt = vec![1000 + k as u32; 17];
        c.submit(ModelTarget::Adapter(AdapterId((k % 5) as u32)), prompt, params)
            .unwrap();
        c.run_until_idle();
        c.take_finished();
    }
    let s = c.stats();
    FleetResult {
        aggregate_adapter_hit_rate: s.aggregate_adapter_hit_rate,
        loads: s.replicas.iter().map(|r| r.adapter_loads).sum(),
        evictions: s.replicas.iter().map(|r| r.adapter_evictions).sum(),
        makespan: c.clock(),
    }
}

fn sizes(quick: bool) -> (usize, usize) {
    if quick {
        (9, 4)
    } else {
        (18, 8)
    }
}

pub fn run(quick: bool) -> Table {
    let (n_requests, rounds) = sizes(quick);
    let mut t = Table::new(
        "adapter_tiering",
        &format!(
            "tiered adapter memory: costed transfers, host-tier demotion, \
             prefetch, and heterogeneous packing ({n_requests} churn \
             requests over 3 adapters; {rounds} fleet rounds over 5)"
        ),
        &[
            "mode",
            "loads",
            "promotions",
            "demotions",
            "host_drops",
            "prefetches",
            "stall_steps",
            "adapter_hit_rate",
            "ttft_mean_s",
            "makespan_s",
        ],
    );
    let arms: [(&str, u64, f64, bool); 4] = [
        ("drop", 0, LOAD_BW, false),
        ("demote", 96, LOAD_BW, false),
        ("demote+prefetch", 96, LOAD_BW, true),
        ("zero-cost", 0, 0.0, false),
    ];
    for (mode, host, bw, prefetch) in arms {
        let p = run_churn(host, bw, prefetch, n_requests);
        t.push(
            &[mode.to_string()],
            &[
                p.loads as f64,
                p.promotions as f64,
                p.demotions as f64,
                p.host_drops as f64,
                p.prefetches as f64,
                p.stall_steps as f64,
                p.adapter_hit_rate,
                p.ttft_mean,
                p.makespan,
            ],
        );
    }
    for hetero in [false, true] {
        let p = run_fleet(hetero, rounds);
        t.push(
            &[if hetero { "fleet-hetero" } else { "fleet-homo" }.to_string()],
            &[
                p.loads as f64,
                0.0,
                0.0,
                0.0,
                0.0,
                0.0,
                p.aggregate_adapter_hit_rate,
                0.0,
                p.makespan,
            ],
        );
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let t = run(true);
        assert_eq!(t.rows.len(), 6); // 4 churn arms + 2 fleet arms
        for v in t.col("makespan_s") {
            assert!(v > 0.0);
        }
    }
}
