//! `concurrency` figure + the handler-contention bench harness (ISSUE 7).
//!
//! Hammers a live [`crate::server::Server`] over real HTTP from 1..=N
//! client threads, each driving its own sessions through delta turns, and
//! reports aggregate turn throughput plus TTFT tails as seen by the
//! clients. The shape under test is the lock-split hot path: handler
//! threads enqueue commands and park on sharded wait slots instead of
//! contending on an engine mutex, so adding client threads must not
//! collapse throughput. Like `scale`, this is a bench-tier figure
//! (reachable via `figure --id concurrency`, deliberately not part of
//! `all`); `bench_concurrency` runs the same harness and writes
//! `BENCH_concurrency.json`.
//!
//! Wall-clock numbers here are REAL time (thread scheduling, TCP), not
//! the virtual clock — they vary run to run. The deterministic columns
//! (sessions, turns) are what CI diffs against the committed baseline;
//! throughput and tails are informational.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use super::Table;
use crate::config::presets;
use crate::engine::Engine;
use crate::pipeline::workload;
use crate::server::Server;
use crate::simulator::SimExecutor;
use crate::util::json::Json;
use crate::util::stats::Samples;

/// One contention run's knobs. Token sizes are small on purpose: the
/// harness measures the serving control plane under handler concurrency
/// (submit queue, waiter shards, session shards), not model compute.
#[derive(Debug, Clone)]
pub struct ContentionConfig {
    /// Concurrent client threads.
    pub threads: usize,
    /// Sessions each thread creates and drives to completion.
    pub sessions_per_thread: usize,
    /// Turns per session (first turn + delta follow-ups).
    pub turns_per_session: usize,
    /// First-turn prompt length (tokens).
    pub first_len: usize,
    /// Follow-up delta length (tokens).
    pub delta_len: usize,
    pub gen_tokens: u32,
}

impl ContentionConfig {
    /// Shared shape; only the thread count sweeps between rows.
    pub fn sized(threads: usize, sessions_per_thread: usize) -> Self {
        ContentionConfig {
            threads,
            sessions_per_thread,
            turns_per_session: 4,
            first_len: 64,
            delta_len: 16,
            gen_tokens: 2,
        }
    }
}

/// What one contention run measured (client-side view).
#[derive(Debug)]
pub struct ContentionReport {
    pub threads: usize,
    pub sessions: u64,
    pub turns: u64,
    /// Real elapsed seconds for the whole run (nondeterministic).
    pub wall_s: f64,
    /// Client-observed TTFT per turn, from the turn summaries.
    pub ttft: Samples,
    /// Mean cache hit rate across delta (non-first) turns — the reuse
    /// signal surviving under concurrency.
    pub delta_hit_rate: f64,
}

impl ContentionReport {
    pub fn turns_per_s(&self) -> f64 {
        self.turns as f64 / self.wall_s.max(1e-9)
    }
}

fn http(addr: SocketAddr, req: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect to bench server");
    s.write_all(req.as_bytes()).expect("write request");
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read response");
    out
}

fn post(addr: SocketAddr, path: &str, body: &str) -> String {
    http(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn body_json(resp: &str) -> Json {
    Json::parse(resp.lines().last().expect("response body")).expect("json body")
}

/// What one client thread brings back: per-turn TTFTs and the delta-turn
/// hit rates it observed.
struct ThreadTally {
    ttfts: Vec<f64>,
    delta_hits: Vec<f64>,
}

/// Deterministic token stream: distinct across (thread, session, turn) so
/// tenants don't accidentally share prefixes, stable across runs so the
/// session/turn counts in the report are exactly reproducible.
fn turn_tokens(th: usize, sess: usize, turn: usize, len: usize, vocab: u32) -> Vec<u32> {
    (0..len)
        .map(|t| ((th * 7919 + sess * 613 + turn * 131 + t) as u32) % vocab)
        .collect()
}

/// Run one contention tier: start a fresh single-replica sim server, turn
/// `threads` client threads loose on it, and collect the client-side
/// tallies. Every response is asserted OK — a single dropped or
/// double-counted turn fails the run, which is the correctness half of
/// the contention story.
pub fn run_contention(cfg: &ContentionConfig) -> ContentionReport {
    let e_cfg = presets::granite_8b();
    let vocab = e_cfg.model.vocab_size;
    let reg = workload::build_registry(2, vocab, true);
    let exec = SimExecutor::new(&e_cfg);
    let mut srv = Server::start(Engine::with_registry(e_cfg, reg, exec), "127.0.0.1:0")
        .expect("bench server start");
    let addr = srv.addr();

    let start = std::time::Instant::now();
    let handles: Vec<std::thread::JoinHandle<ThreadTally>> = (0..cfg.threads)
        .map(|th| {
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let mut tally = ThreadTally { ttfts: Vec::new(), delta_hits: Vec::new() };
                for sess in 0..cfg.sessions_per_thread {
                    let r = post(
                        addr,
                        "/v1/sessions",
                        &format!(r#"{{"cache_salt": {}}}"#, th * 100_003 + sess),
                    );
                    assert!(r.contains("200 OK"), "create: {r}");
                    let sid = body_json(&r)
                        .get("session")
                        .and_then(Json::as_u64)
                        .expect("session id");
                    for turn in 0..cfg.turns_per_session {
                        let len = if turn == 0 { cfg.first_len } else { cfg.delta_len };
                        let tokens = turn_tokens(th, sess, turn, len, vocab);
                        let toks: Vec<String> =
                            tokens.iter().map(u32::to_string).collect();
                        let body = format!(
                            r#"{{"tokens": [{}], "max_new_tokens": {}}}"#,
                            toks.join(","),
                            cfg.gen_tokens
                        );
                        let r = post(addr, &format!("/v1/sessions/{sid}/turns"), &body);
                        assert!(r.contains("200 OK"), "turn: {r}");
                        let j = body_json(&r);
                        tally.ttfts.push(
                            j.get("ttft_s").and_then(Json::as_f64).expect("ttft_s"),
                        );
                        if turn > 0 {
                            tally.delta_hits.push(
                                j.get("cache_hit_rate")
                                    .and_then(Json::as_f64)
                                    .expect("cache_hit_rate"),
                            );
                        }
                    }
                    let r = http(
                        addr,
                        &format!("DELETE /v1/sessions/{sid} HTTP/1.1\r\nHost: x\r\n\r\n"),
                    );
                    assert!(r.contains("200 OK"), "delete: {r}");
                }
                tally
            })
        })
        .collect();
    let tallies: Vec<ThreadTally> =
        handles.into_iter().map(|h| h.join().expect("client thread")).collect();
    let wall_s = start.elapsed().as_secs_f64();
    srv.shutdown();

    let mut ttft = Samples::new();
    let (mut hit_sum, mut hit_n) = (0.0f64, 0u64);
    for t in &tallies {
        for &v in &t.ttfts {
            ttft.push(v);
        }
        for &h in &t.delta_hits {
            hit_sum += h;
            hit_n += 1;
        }
    }
    let sessions = (cfg.threads * cfg.sessions_per_thread) as u64;
    ContentionReport {
        threads: cfg.threads,
        sessions,
        turns: sessions * cfg.turns_per_session as u64,
        wall_s,
        ttft,
        delta_hit_rate: if hit_n == 0 { 0.0 } else { hit_sum / hit_n as f64 },
    }
}

/// The `concurrency` figure: a client-thread sweep over one server. The
/// acceptance shape: the session/turn counts are exact at every tier
/// (nothing lost, nothing duplicated under contention) and delta turns
/// keep their cache hits; throughput columns are informational real-time.
pub fn run(quick: bool) -> Table {
    let (threads, per): (&[usize], usize) =
        if quick { (&[1, 2, 4, 8], 4) } else { (&[1, 2, 4, 8, 16], 8) };
    let mut t = Table::new(
        "concurrency",
        "handler-contention sweep: turn throughput + TTFT tails vs client threads",
        &[
            "threads",
            "sessions",
            "turns",
            "wall_s",
            "turns_per_s",
            "ttft_p50_s",
            "ttft_p99_s",
            "delta_hit_rate",
        ],
    );
    for &n in threads {
        let cfg = ContentionConfig::sized(n, per);
        let r = run_contention(&cfg);
        assert_eq!(r.sessions, (n * per) as u64);
        assert_eq!(r.turns, (n * per * cfg.turns_per_session) as u64);
        let row = [
            n as f64,
            r.sessions as f64,
            r.turns as f64,
            r.wall_s,
            r.turns_per_s(),
            r.ttft.percentile(50.0),
            r.ttft.p99(),
            r.delta_hit_rate,
        ];
        t.push(&[], &row);
    }
    t
}
