//! Figure 12 (Appendix D): TTFT (= queue + prefill) and inference time
//! (= prefill + decode) of the evaluation step in the base-adapter
//! pipeline — the two aggregate views whose optimization trade-off the
//! appendix discusses.

use crate::pipeline::PipelineSpec;

use super::{run_sync_pair, Table};

pub fn run(quick: bool) -> Table {
    let lens = super::prompt_sweep(quick);
    let mut t = Table::new(
        "fig12",
        "base-adapter eval: TTFT and inference time vs prompt length",
        &["prompt_len", "variant", "ttft(s)", "inference(s)", "ttft_x", "inference_x"],
    );
    let max_spec = PipelineSpec::base_adapter(*lens.last().unwrap(), 256, 16);
    let cfg = crate::config::presets::granite_8b();
    let batch = crate::pipeline::workload::batch_size_for(&cfg, max_spec.max_total_len());
    for &plen in &lens {
        let spec = PipelineSpec::base_adapter(plen, 256, 16);
        let pair = run_sync_pair("granite-8b", &spec, batch, 42);
        let a = pair.alora.eval_latencies();
        let l = pair.lora.eval_latencies();
        let ttft_x = l.mean("ttft") / a.mean("ttft");
        let inf_x = l.mean("inference") / a.mean("inference");
        for (name, r) in [("aLoRA", &a), ("LoRA", &l)] {
            t.push(
                &[plen.to_string(), name.to_string()],
                &[r.mean("ttft"), r.mean("inference"), ttft_x, inf_x],
            );
        }
    }
    t
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig12_ttft_speedup_exceeds_inference_speedup_at_long_prompts() {
        let t = super::run(true);
        let ttft_x = t.col("ttft_x");
        let inf_x = t.col("inference_x");
        let n = ttft_x.len();
        // TTFT includes queue savings on top of prefill — at the longest
        // prompt it is the paper's ">100x" headline metric.
        assert!(ttft_x[n - 1] > 1.0 && inf_x[n - 1] > 1.0);
        assert!(
            ttft_x[n - 1] >= inf_x[n - 1] * 0.8,
            "ttft_x={:?} inf_x={:?}",
            ttft_x,
            inf_x
        );
    }
}
