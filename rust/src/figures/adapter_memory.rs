//! Adapter-memory figure (ours, beyond the paper): what unified
//! KV + adapter-weight accounting costs and buys.
//!
//! Sweeps adapter count × device-memory budget on the same multi-adapter
//! Poisson workload, in two modes per point:
//!
//! - **paged** — the tentpole: adapter weights page against the KV block
//!   budget (S-LoRA-style), loads evict idle adapters / cold cache, and
//!   admission gates on residency.
//! - **resident** — the pre-refactor baseline: weights are free and every
//!   adapter is permanently resident (`adapter_paging = false`), i.e. the
//!   engine pretends the GPU has unbounded room for weights.
//!
//! The headline shape: with a budget that holds every adapter, paged mode
//! is behaviorally identical to the baseline (the acceptance test pins
//! this bit-exactly); as the budget shrinks below `adapters × weight`,
//! residency hit-rate falls and reload churn + admission stalls surface as
//! TTFT — the real cost the always-resident model was hiding.

use crate::adapter::AdapterId;
use crate::config::{presets, EngineConfig};
use crate::engine::Engine;
use crate::pipeline::{self, workload, PipelineKind, PipelineSpec};
use crate::simulator::SimExecutor;

use super::Table;

/// One (adapters, budget, mode) measurement.
#[derive(Debug, Clone)]
pub struct PointResult {
    pub makespan: f64,
    pub ttft_mean: f64,
    pub e2e_mean: f64,
    pub prefix_hit_rate: f64,
    /// Residency hit-rate over adapter admissions (0 in resident mode:
    /// the always-resident baseline doesn't count, it never loads).
    pub adapter_hit_rate: f64,
    pub loads: u64,
    pub evictions: u64,
    pub stall_steps: u64,
    /// Per-request behavioral fingerprint (id, cached tokens, finish time)
    /// — what "bit-identical to always-resident" is asserted over.
    pub output_fingerprint: Vec<(u64, usize, f64)>,
}

/// Engine config for one point: granite-8b cost model, shrunk to a
/// `budget_blocks`-page device so adapter weights (32 pages per rank-32
/// aLoRA) genuinely compete with KV.
pub fn cfg_for(budget_blocks: u64, paged: bool) -> EngineConfig {
    let mut cfg = presets::granite_8b();
    cfg.scheduler.max_seq_len = 2048;
    cfg.scheduler.max_batch_tokens = 2048;
    cfg.scheduler.max_num_seqs = 32;
    cfg.cache.max_kv_tokens = budget_blocks * cfg.cache.block_size as u64;
    cfg.cache.adapter_paging = paged;
    cfg
}

fn spec(n_adapters: u32) -> PipelineSpec {
    // One conversation = base draft → one eval per adapter → consolidated
    // base call: every conversation touches EVERY adapter, the worst case
    // for residency churn.
    PipelineSpec {
        kind: PipelineKind::MultiAdapter,
        prompt_len: 256,
        base_gen: 32,
        eval_gen: 8,
        adapters: (0..n_adapters).map(AdapterId).collect(),
        base2_gen: 16,
        priority_continuations: false,
    }
}

pub fn run_point(n_adapters: u32, budget_blocks: u64, paged: bool, n_conv: usize) -> PointResult {
    let cfg = cfg_for(budget_blocks, paged);
    let reg = workload::build_registry(n_adapters, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    let mut e = Engine::with_registry(cfg, reg, exec);
    let r = pipeline::run_poisson(&mut e, &spec(n_adapters), n_conv, 2.0, 42);
    let rs = e.residency().stats();
    PointResult {
        makespan: r.makespan,
        ttft_mean: e.metrics.all.mean("ttft"),
        e2e_mean: e.metrics.all.mean("e2e"),
        prefix_hit_rate: e.metrics.cache_hit_rate(),
        adapter_hit_rate: rs.hit_rate(),
        loads: rs.loads,
        evictions: rs.evictions,
        stall_steps: rs.load_stall_steps,
        output_fingerprint: r
            .outputs
            .iter()
            .map(|(_, o)| (o.id.0, o.num_cached_tokens, o.timeline.finished))
            .collect(),
    }
}

fn grid(quick: bool) -> (Vec<u32>, Vec<u64>, usize) {
    if quick {
        (vec![4, 8], vec![256, 512], 8)
    } else {
        (vec![4, 8, 16], vec![256, 512, 1024], 24)
    }
}

pub fn run(quick: bool) -> Table {
    let (adapter_counts, budgets, n_conv) = grid(quick);
    let mut t = Table::new(
        "adapter_memory",
        &format!(
            "unified adapter+KV memory budget: residency hit-rate and TTFT \
             vs always-resident baseline ({n_conv} conversations @ 2/s, \
             32 weight blocks per adapter)"
        ),
        &[
            "adapters",
            "budget_blocks",
            "mode",
            "adapter_hit_rate",
            "loads",
            "evictions",
            "stall_steps",
            "prefix_hit_rate",
            "ttft_mean_s",
            "e2e_mean_s",
            "makespan_s",
        ],
    );
    for &n in &adapter_counts {
        for &b in &budgets {
            for paged in [true, false] {
                let p = run_point(n, b, paged, n_conv);
                t.push(
                    &[
                        n.to_string(),
                        b.to_string(),
                        if paged { "paged" } else { "resident" }.to_string(),
                    ],
                    &[
                        p.adapter_hit_rate,
                        p.loads as f64,
                        p.evictions as f64,
                        p.stall_steps as f64,
                        p.prefix_hit_rate,
                        p.ttft_mean,
                        p.e2e_mean,
                        p.makespan,
                    ],
                );
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape_and_paging_pressure_direction() {
        let t = run(true);
        assert_eq!(t.rows.len(), 8); // 2 adapter counts × 2 budgets × 2 modes
        for v in t.col("makespan_s") {
            assert!(v > 0.0);
        }
        // Paged rows load at least once per adapter; resident rows never.
        let loads = t.col("loads");
        let evictions = t.col("evictions");
        for (i, row) in t.rows.iter().enumerate() {
            if row[2] == "paged" {
                assert!(loads[i] > 0.0, "row {i} paged but never loaded");
            } else {
                assert_eq!(loads[i], 0.0, "resident baseline must not page");
                assert_eq!(evictions[i], 0.0);
            }
        }
    }

    #[test]
    fn shrinking_budget_increases_churn() {
        // 8 adapters × 32 = 256 weight blocks: a 256-block budget cannot
        // hold them beside KV, a 1024-block budget holds them all.
        let tight = run_point(8, 256, true, 6);
        let roomy = run_point(8, 1024, true, 6);
        assert!(tight.evictions > 0, "tight budget must evict: {tight:?}");
        assert!(tight.loads > 8, "tight budget must reload: {tight:?}");
        assert_eq!(roomy.loads, 8, "roomy budget loads each adapter once");
        assert_eq!(roomy.evictions, 0);
        assert!(roomy.adapter_hit_rate > tight.adapter_hit_rate);
    }
}
