//! Table-2-style per-stage breakdown of an arbitrary stage-graph pipeline.
//!
//! Not a figure from the paper: this exercises the coordinator the way the
//! paper's Table 2 slices a fixed pipeline — per-stage E2E / queue /
//! prefill / decode / TTFT — but over a general DAG (draft → 3 parallel
//! adapter evals → consolidated base call), for the aLoRA engine and the
//! standard-LoRA baseline. Any graph shape yields the same breakdown via
//! `metrics.stage` / `CoordinatorResult::latencies_of` (DESIGN.md §6).

use crate::adapter::AdapterId;
use crate::coordinator::{Coordinator, StageGraph, StageId};
use crate::pipeline::workload;
use crate::request::ModelTarget;
use crate::util::rng::Rng;

use super::{make_engine, Table};

fn dag(prompt: Vec<u32>, vocab: u32, n_adapters: u32) -> StageGraph {
    let mut g = StageGraph::new();
    let draft = g.root("draft", ModelTarget::Base, prompt, 128);
    let evals: Vec<StageId> = (0..n_adapters)
        .map(|a| {
            g.chain(
                &format!("eval-{a}"),
                ModelTarget::Adapter(AdapterId(a)),
                draft,
                workload::invocation_for(vocab, a),
                16,
            )
        })
        .collect();
    g.consolidate("consolidate", ModelTarget::Base, draft, &evals, Vec::new(), 32);
    g
}

pub fn run() -> Table {
    let conversations = 8;
    let n_adapters = 3;
    let mut t = Table::new(
        "table2",
        "per-stage breakdown, 5-stage DAG (draft -> 3 evals -> consolidate), granite-8b",
        &[
            "variant", "stage", "count", "e2e_s", "queue_s", "prefill_s", "decode_s", "ttft_s",
            "hit_rate",
        ],
    );
    for (variant, alora) in [("aLoRA", true), ("LoRA", false)] {
        let mut engine = make_engine("granite-8b", alora, n_adapters);
        let vocab = engine.cfg.model.vocab_size;
        let mut rng = Rng::new(42);
        let graphs: Vec<StageGraph> = (0..conversations)
            .map(|_| dag(workload::prompt(&mut rng, 1024, vocab), vocab, n_adapters))
            .collect();
        let arrivals = vec![0.0; conversations];
        let r = Coordinator::run_event(&mut engine, graphs, &arrivals).expect("table2 run");
        for name in r.stage_names() {
            let lat = r.latencies_of(&name);
            t.push(
                &[variant.to_string(), name.clone()],
                &[
                    lat.count() as f64,
                    lat.mean("e2e"),
                    lat.mean("queue"),
                    lat.mean("prefill"),
                    lat.mean("decode"),
                    lat.mean("ttft"),
                    r.hit_rate_of(&name),
                ],
            );
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_covers_every_stage_for_both_variants() {
        let t = run();
        // 5 distinct stage names × 2 variants
        assert_eq!(t.rows.len(), 10);
        let hits = t.col("hit_rate");
        for (i, row) in t.rows.iter().enumerate() {
            let (variant, stage) = (&row[0], &row[1]);
            // aLoRA: every non-root stage reuses upstream KV.
            if variant == "aLoRA" && stage != "draft" {
                assert!(hits[i] > 0.0, "{variant}/{stage}: {}", hits[i]);
            }
            // LoRA baseline: adapter evals are cache-isolated (base→base
            // reuse at the consolidation stage is allowed either way).
            if variant == "LoRA" && stage.starts_with("eval") {
                assert_eq!(hits[i], 0.0, "{variant}/{stage}: {}", hits[i]);
            }
        }
    }
}
