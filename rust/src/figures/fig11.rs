//! Figure 11 (Appendix C): adapter-base pipeline — reuse in the reverse
//! direction. Adapter evaluates the prompt first (eval 256), then the base
//! model generates (16), reusing the adapter's pre-activation blocks.

use crate::adapter::AdapterId;
use crate::pipeline::{PipelineKind, PipelineSpec};

use super::{run_sync_pair, Table};

pub fn run(quick: bool) -> Table {
    let lens = super::prompt_sweep(quick);
    let mut t = Table::new(
        "fig11",
        "adapter-base: base-step latencies vs prompt length (reverse reuse)",
        &["prompt_len", "variant", "e2e(s)", "queue(s)", "prefill(s)", "decode(s)", "base_hit"],
    );
    let spec_max = PipelineSpec {
        kind: PipelineKind::AdapterBase,
        prompt_len: *lens.last().unwrap(),
        base_gen: 0,
        eval_gen: 256,
        adapters: vec![AdapterId(0)],
        base2_gen: 16,
        priority_continuations: false,
    };
    let cfg = crate::config::presets::granite_8b();
    let batch = crate::pipeline::workload::batch_size_for(&cfg, spec_max.max_total_len());
    for &plen in &lens {
        let spec = PipelineSpec { prompt_len: plen, ..spec_max.clone() };
        let pair = run_sync_pair("granite-8b", &spec, batch, 42);
        for (name, r) in [("aLoRA", &pair.alora), ("LoRA", &pair.lora)] {
            let b2 = r.base2_latencies();
            let hit: f64 = {
                let hits: Vec<f64> = r
                    .outputs
                    .iter()
                    .filter(|(s, _)| *s == crate::pipeline::Stage::Base2)
                    .map(|(_, o)| o.cache_hit_rate())
                    .collect();
                hits.iter().sum::<f64>() / hits.len().max(1) as f64
            };
            t.push(
                &[plen.to_string(), name.to_string()],
                &[b2.mean("e2e"), b2.mean("queue"), b2.mean("prefill"), b2.mean("decode"), hit],
            );
        }
    }
    t
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig11_base_reuses_adapter_blocks() {
        let t = super::run(true);
        let hits = t.col("base_hit");
        let e2e = t.col("e2e(s)");
        // rows alternate aLoRA / LoRA per prompt length. Only the
        // pre-activation span (the prompt) is base-reusable, so the hit
        // fraction is ~prompt/(prompt + eval_out) and grows with prompt.
        let alora_hits: Vec<f64> = hits.iter().step_by(2).copied().collect();
        assert!(alora_hits.iter().all(|&h| h > 0.25), "{alora_hits:?}");
        assert!(
            alora_hits.last().unwrap() > alora_hits.first().unwrap(),
            "{alora_hits:?}"
        );
        for pair in hits.chunks(2) {
            assert_eq!(pair[1], 0.0, "LoRA blocks are adapter-salted");
        }
        let last = e2e.len() - 2;
        assert!(e2e[last] < e2e[last + 1], "aLoRA base step faster at long prompts");
    }
}
