//! Figure 9: E2E speedup vs arrival rate × sequence (generation) length —
//! including the cache-overflow droop.
//!
//! Paper: speedups accelerate with longer sequences and higher rates, but
//! once the KV cache capacity is exceeded, previously cached blocks are
//! overwritten before reuse and the speedup collapses — load must be
//! balanced to stay under capacity.

use crate::pipeline::PipelineSpec;

use super::{run_poisson_pair, Table};

pub fn grid(quick: bool) -> (Vec<f64>, Vec<usize>) {
    if quick {
        (vec![1.0, 8.0], vec![256, 4096])
    } else {
        (vec![0.5, 1.0, 2.0, 4.0, 8.0, 16.0], vec![256, 1024, 4096, 16384])
    }
}

pub fn run(quick: bool) -> Table {
    let n = if quick { 80 } else { 500 };
    let (rates, gens) = grid(quick);
    let mut t = Table::new(
        "fig9",
        &format!("async E2E speedup vs arrival rate × generation length (n={n})"),
        &["gen_len", "rate(req/s)", "e2e_speedup", "alora_hit_rate", "evictions"],
    );
    for &gen in &gens {
        for &rate in &rates {
            let spec = PipelineSpec::base_adapter(256, gen as u32, 16);
            let pair = run_poisson_pair("granite-8b", &spec, n, rate, 42);
            let speedup =
                pair.lora.eval_latencies().mean("e2e") / pair.alora.eval_latencies().mean("e2e");
            t.push(
                &[gen.to_string(), format!("{rate}")],
                &[speedup, pair.alora.eval_hit_rate(), 0.0],
            );
        }
    }
    t
}

/// Cache-overflow probe: run one (rate, gen) point on a deliberately tiny
/// KV cache and report hit-rate collapse (used by tests and the bench).
pub fn overflow_probe() -> (f64, f64) {
    use crate::pipeline::{run_poisson, workload};
    let spec = PipelineSpec::base_adapter(256, 2048, 16);

    let small = super::make_engine("granite-8b", true, 1);
    // Shrink capacity to ~6 concurrent conversations' worth.
    let mut cfg = small.cfg.clone();
    cfg.cache.max_kv_tokens = 16_384;
    cfg.scheduler.max_seq_len = 16_384;
    let reg = workload::build_registry(1, cfg.model.vocab_size, true);
    let exec = crate::simulator::SimExecutor::new(&cfg);
    let mut small = crate::engine::Engine::with_registry(cfg, reg, exec);
    let r_small = run_poisson(&mut small, &spec, 60, 8.0, 42);

    let mut big = super::make_engine("granite-8b", true, 1);
    let r_big = run_poisson(&mut big, &spec, 60, 8.0, 42);
    (r_small.eval_hit_rate(), r_big.eval_hit_rate())
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig9_longer_sequences_bigger_speedups() {
        let t = super::run(true);
        let sp = t.col("e2e_speedup");
        // grid rows: gen=256 × 2 rates, then gen=4096 × 2 rates.
        let short_best = sp[0].max(sp[1]);
        let long_best = sp[2].max(sp[3]);
        assert!(long_best > short_best, "{sp:?}");
    }

    #[test]
    fn fig9_cache_overflow_collapses_hits() {
        let (small, big) = super::overflow_probe();
        assert!(
            small < big * 0.8,
            "undersized cache must lose reuse: small={small:.2} big={big:.2}"
        );
    }
}
