//! Self-driving fleet (DESIGN.md §19): the failure detector and the
//! autoscaler doing, unattended, what the failover and scale figures do
//! with an operator in the loop.
//!
//! Two arms, two tables:
//!
//! * `selfdriving_detect` — 4 replicas serve sticky multi-turn sessions
//!   in rounds; mid-round the victim replica goes *silent* (a partition,
//!   no admin call). The heartbeat monitor walks it Up → Suspected →
//!   Down in exactly `down_after_misses` steps, the ordinary failover
//!   pipeline evacuates it, and the per-round hit-rate shows the same
//!   dip-and-re-warm curve as the operator-declared failover figure —
//!   with zero lost requests.
//!
//! * `selfdriving_autoscale` — a 3-slot fleet (1 active + 2 standby)
//!   rides a diurnal load cycle: night (idle), day (burst), night. The
//!   autoscaler activates standbys under sustained queue pressure, routes
//!   the second wave across the grown fleet, then drains back down to
//!   the minimum when the queues empty — again with zero lost requests.

use crate::cluster::{Cluster, RoutePolicy, RouterConfig};
use crate::config::{presets, FleetConfig};
use crate::engine::{Engine, EngineDriver};
use crate::pipeline::workload;
use crate::request::session::SessionId;
use crate::request::{ModelTarget, RequestId, RequestOutput, SamplingParams};
use crate::session::SessionManager;
use crate::simulator::SimExecutor;
use crate::util::fxmap::FxHashMap;

use super::Table;

pub const REPLICAS: usize = 4;
pub const VICTIM: usize = 1;
/// Round whose in-flight burst the silence interrupts.
pub const SILENCE_ROUND: usize = 2;

/// Both arms' measurements, exposed for the acceptance assertions.
pub struct SelfDrivingCurves {
    pub detect: Table,
    pub autoscale: Table,
    /// Detection arm: per-round token hit-rate.
    pub hit_rates: Vec<f64>,
    /// Steps from silence to the detector-declared failover.
    pub detection_steps: u32,
    pub requeued: u64,
    pub turns_submitted: usize,
    pub turns_completed: usize,
    /// Autoscale arm: most replicas simultaneously active.
    pub peak_active: usize,
    pub final_active: usize,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub reqs_submitted: usize,
    pub reqs_completed: usize,
}

impl SelfDrivingCurves {
    /// The post-detection dip: the worst round from the silence on.
    pub fn dip(&self) -> f64 {
        self.hit_rates[SILENCE_ROUND..]
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
    }

    /// Steady state after re-warming (the last round).
    pub fn recovered(&self) -> f64 {
        *self.hit_rates.last().expect("at least one round")
    }
}

/// Detection arm: sticky sessions across a silent-replica failover.
fn run_detect(quick: bool) -> (Table, Vec<f64>, u32, u64, usize, usize) {
    let n_sessions = if quick { 16 } else { 48 };
    let rounds = if quick { 6 } else { 10 };
    let mut c: Cluster<SimExecutor> =
        Cluster::from_factory(REPLICAS, RoutePolicy::PrefixAffinity, |_| {
            super::make_engine("granite-8b", true, 2)
        })
        .expect("cluster construction");
    let down_after = c.fleet_config().down_after_misses;
    let mut mgr = SessionManager::new();
    let sessions: Vec<SessionId> = (0..n_sessions).map(|_| mgr.create(0)).collect();

    let mut table = Table::new(
        "selfdriving_detect",
        &format!(
            "per-round fleet hit-rate across a detector-declared failover \
             ({REPLICAS} replicas, {n_sessions} sticky sessions, replica \
             {VICTIM} silenced mid-round {SILENCE_ROUND}, no admin call)"
        ),
        &[
            "round",
            "phase",
            "hit_rate",
            "ttft_mean_s",
            "detection_steps",
            "requeued",
            "detected_failures",
        ],
    );
    let mut hit_rates = Vec::with_capacity(rounds);
    let mut detection_steps = 0u32;
    let (mut completed, mut submitted) = (0usize, 0usize);

    for round in 0..rounds {
        let mut pending: Vec<(SessionId, RequestId)> = Vec::with_capacity(sessions.len());
        for (si, &sid) in sessions.iter().enumerate() {
            let base = (si as u32 + 1) * 10_000 + round as u32 * 100;
            let delta: Vec<u32> = if round == 0 {
                (base..base + 256).collect()
            } else {
                (base..base + 32).collect()
            };
            let (_turn, rid) = mgr
                .begin_turn(&mut c, sid, ModelTarget::Base, delta, 16, true)
                .expect("turn submission");
            pending.push((sid, rid));
        }
        submitted += pending.len();

        if round == SILENCE_ROUND {
            // Mid-burst the victim stops heartbeating. Nobody calls the
            // admin API: the monitor itself must notice, declare the
            // failover, and hand the serving layer the same report an
            // operator-declared kill produces.
            for _ in 0..3 {
                c.step();
            }
            c.silence_replica(VICTIM).expect("silence fault injection");
            let report = loop {
                assert!(c.step(), "cluster stalled while detection pending");
                detection_steps += 1;
                if let Some(r) = c.take_failover_reports().pop() {
                    break r;
                }
                assert!(
                    detection_steps <= down_after,
                    "detection latency exceeded down_after_misses"
                );
            };
            assert_eq!(
                detection_steps, down_after,
                "detection latency must equal the miss threshold exactly"
            );
            assert!(report.rejected.is_empty(), "identical survivors must accept");
            mgr.repair_after_failover(&mut c, &report);
        }

        let mut outs: FxHashMap<RequestId, RequestOutput> = FxHashMap::default();
        loop {
            for o in c.take_finished() {
                outs.insert(o.id, o);
            }
            if pending.iter().all(|(_, rid)| outs.contains_key(rid)) {
                break;
            }
            assert!(c.step(), "cluster stalled with turns outstanding");
        }
        let (mut cached, mut prompted, mut ttft_sum) = (0usize, 0usize, 0.0f64);
        for (sid, rid) in &pending {
            let out = outs.remove(rid).expect("drained above");
            let rec = mgr.complete_turn(&mut c, *sid, &out).expect("turn completion");
            cached += rec.cached_tokens;
            prompted += rec.prompt_len;
            ttft_sum += rec.ttft_s;
            completed += 1;
        }
        let hit = cached as f64 / prompted as f64;
        hit_rates.push(hit);
        let phase = match round.cmp(&SILENCE_ROUND) {
            std::cmp::Ordering::Less => "pre-silence",
            std::cmp::Ordering::Equal => "detected-failover",
            std::cmp::Ordering::Greater => "recovery",
        };
        let stats = &c.router().stats;
        table.push(
            &[round.to_string(), phase.to_string()],
            &[
                hit,
                ttft_sum / pending.len() as f64,
                detection_steps as f64,
                stats.requeued_requests as f64,
                stats.detected_failures as f64,
            ],
        );
    }

    let requeued = c.router().stats.requeued_requests;
    (table, hit_rates, detection_steps, requeued, submitted, completed)
}

/// One tiny-preset replica for the autoscale arm (small queues make the
/// pressure signal cheap to saturate).
fn tiny_engine() -> Engine<SimExecutor> {
    let cfg = presets::tiny();
    let reg = workload::build_registry(2, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

/// Autoscale arm: diurnal load over a 1-active + 2-standby fleet.
fn run_autoscale(quick: bool) -> (Table, usize, usize, u64, u64, usize, usize) {
    let wave = if quick { 24 } else { 48 };
    let fleet = FleetConfig {
        autoscale: true,
        min_replicas: 1,
        scale_up_after_steps: 2,
        scale_down_after_steps: 4,
        queue_high: 2.0,
        queue_low: 0.5,
        cooldown_steps: 2,
        warmup_min_blocks: 4,
        ..Default::default()
    };
    let mut c = Cluster::with_fleet(
        vec![tiny_engine(), tiny_engine(), tiny_engine()],
        RouterConfig { policy: RoutePolicy::LeastLoaded, ..Default::default() },
        fleet,
        1,
    )
    .expect("fleet construction");

    let mut table = Table::new(
        "selfdriving_autoscale",
        &format!(
            "diurnal load over a 1-active/2-standby fleet \
             (two {wave}-request day waves between idle nights)"
        ),
        &["phase", "active_replicas", "scale_ups", "scale_downs", "completed"],
    );
    let mut ids: Vec<RequestId> = Vec::new();
    let mut done: FxHashMap<RequestId, ()> = FxHashMap::default();
    let mut peak_active = c.num_healthy();
    let p = SamplingParams { max_new_tokens: 12, ..Default::default() };
    let submit_wave = |c: &mut Cluster<SimExecutor>, ids: &mut Vec<RequestId>, salt: u32| {
        for i in 0..wave {
            let base = salt + i as u32 * 7;
            let prompt: Vec<u32> = (0..48).map(|t| (base + t) % 480).collect();
            ids.push(c.submit(ModelTarget::Base, prompt, p).expect("submission"));
        }
    };

    // Night 0: a becalmed fleet holds at the minimum.
    for _ in 0..8 {
        c.step();
    }
    let stats = &c.router().stats;
    table.push(
        &["night0".to_string()],
        &[
            c.num_healthy() as f64,
            stats.scale_ups as f64,
            stats.scale_downs as f64,
            done.len() as f64,
        ],
    );

    // Day: wave one saturates the single active replica; sustained queue
    // pressure activates standbys. Wave two lands on the grown fleet.
    submit_wave(&mut c, &mut ids, 1);
    for _ in 0..8 {
        c.step();
        peak_active = peak_active.max(c.num_healthy());
        for o in c.take_finished() {
            done.insert(o.id, ());
        }
    }
    submit_wave(&mut c, &mut ids, 5000);
    let mut guard = 0;
    while done.len() < ids.len() {
        assert!(c.step(), "fleet stalled with requests outstanding");
        peak_active = peak_active.max(c.num_healthy());
        for o in c.take_finished() {
            done.insert(o.id, ());
        }
        guard += 1;
        assert!(guard < 5000, "day traffic failed to drain");
    }
    let stats = &c.router().stats;
    table.push(
        &["day".to_string()],
        &[
            peak_active as f64,
            stats.scale_ups as f64,
            stats.scale_downs as f64,
            done.len() as f64,
        ],
    );

    // Night 1: sustained idleness drains the extra replicas back to
    // standby (one victim at a time, each fully drained before retiring).
    // Wait for full retirement — `scale_downs` counts only completed
    // drains, and a victim is Draining (not Up) while it empties.
    let retired = c.num_replicas() - 1;
    let mut guard = 0;
    while c.num_standby() < retired {
        c.step();
        guard += 1;
        assert!(guard < 1000, "fleet failed to descale when idle");
    }
    let stats = &c.router().stats;
    table.push(
        &["night1".to_string()],
        &[
            c.num_healthy() as f64,
            stats.scale_ups as f64,
            stats.scale_downs as f64,
            done.len() as f64,
        ],
    );

    let (ups, downs) = (c.router().stats.scale_ups, c.router().stats.scale_downs);
    (table, peak_active, c.num_healthy(), ups, downs, ids.len(), done.len())
}

pub fn run_curves(quick: bool) -> SelfDrivingCurves {
    let (detect, hit_rates, detection_steps, requeued, turns_submitted, turns_completed) =
        run_detect(quick);
    let (autoscale, peak_active, final_active, scale_ups, scale_downs, reqs_submitted, reqs_completed) =
        run_autoscale(quick);
    SelfDrivingCurves {
        detect,
        autoscale,
        hit_rates,
        detection_steps,
        requeued,
        turns_submitted,
        turns_completed,
        peak_active,
        final_active,
        scale_ups,
        scale_downs,
        reqs_submitted,
        reqs_completed,
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let curves = run_curves(quick);
    vec![curves.detect, curves.autoscale]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silence_detection_dips_and_rewarm_with_zero_losses() {
        let curves = run_curves(true);
        // Zero lost requests across the detector-declared failover.
        assert_eq!(curves.turns_completed, curves.turns_submitted);
        // Detection fired at the configured threshold and moved work.
        assert_eq!(curves.detection_steps, FleetConfig::default().down_after_misses);
        assert!(curves.requeued > 0, "no in-flight work was requeued");
        // Warm before, dip at the failover, re-warm after.
        let pre = curves.hit_rates[SILENCE_ROUND - 1];
        assert!(pre > 0.8, "pre-silence steady state not warm: {pre:.3}");
        let dip = curves.dip();
        assert!(dip < pre, "silence produced no dip: {:?}", curves.hit_rates);
        let rec = curves.recovered();
        assert!(rec > dip, "failed to re-warm: dip {dip:.3}, final {rec:.3}");
        assert!(rec > 0.8, "recovery did not re-warm: {rec:.3}");
    }

    #[test]
    fn diurnal_load_scales_up_then_back_down_with_zero_losses() {
        let curves = run_curves(true);
        assert_eq!(curves.reqs_completed, curves.reqs_submitted, "lost requests");
        assert!(curves.peak_active >= 2, "day pressure never grew the fleet");
        assert_eq!(curves.final_active, 1, "night did not drain back to minimum");
        assert!(curves.scale_ups >= 1);
        assert_eq!(curves.scale_ups, curves.scale_downs, "every scale-up was undone");
    }

    #[test]
    fn table_shapes() {
        let tables = run(true);
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].id, "selfdriving_detect");
        assert_eq!(tables[0].rows.len(), 6);
        for v in tables[0].col("hit_rate") {
            assert!((0.0..=1.0).contains(&v));
        }
        assert!(tables[0].col("detected_failures").last().copied().unwrap() >= 1.0);
        assert_eq!(tables[1].id, "selfdriving_autoscale");
        assert_eq!(tables[1].rows.len(), 3);
        assert_eq!(tables[1].col("active_replicas").first().copied().unwrap(), 1.0);
        assert_eq!(tables[1].col("active_replicas").last().copied().unwrap(), 1.0);
    }
}
