//! Ablations over the design choices DESIGN.md calls out — not paper
//! figures, but the studies a systems reviewer would ask for:
//!
//! - **block size**: reuse granularity vs paging overhead. Small blocks
//!   cache more of a partially-shared prefix (the invocation tail wastes
//!   less) but allocate more often.
//! - **chunked-prefill budget**: head-of-line blocking vs decode
//!   interference (paper §2.5 / §4.2.1).
//! - **prefix caching on/off**: isolates how much of the aLoRA win is the
//!   cache itself vs the scheduler.
//! - **eviction pressure**: hit rate as capacity shrinks (free-pool LRU).

use crate::config::presets;
use crate::engine::Engine;
use crate::pipeline::{self, workload, PipelineSpec};
use crate::simulator::SimExecutor;

use super::Table;

fn engine_with(
    block_size: u32,
    budget: u32,
    prefix_caching: bool,
    kv_tokens: Option<u64>,
) -> Engine<SimExecutor> {
    let mut cfg = presets::granite_8b();
    cfg.cache.block_size = block_size;
    cfg.scheduler.max_batch_tokens = budget;
    cfg.cache.enable_prefix_caching = prefix_caching;
    if let Some(t) = kv_tokens {
        cfg.cache.max_kv_tokens = t;
        cfg.scheduler.max_seq_len = cfg.scheduler.max_seq_len.min(t as u32 / 2);
        // keep max_seq_len a block multiple
        cfg.scheduler.max_seq_len -= cfg.scheduler.max_seq_len % block_size;
    }
    let reg = workload::build_registry(1, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

/// Block-size sweep: eval hit rate + e2e + allocations per request.
pub fn block_size_sweep() -> Table {
    let mut t = Table::new(
        "ablation-block-size",
        "block size vs hit rate / eval e2e / block allocations (base-adapter, prompt 1024)",
        &["block_size", "hit_rate", "eval_e2e(s)", "blocks_alloc"],
    );
    let spec = PipelineSpec::base_adapter(1024, 256, 16);
    for bs in [8u32, 16, 32, 64, 128] {
        let mut e = engine_with(bs, 8192, true, None);
        let r = pipeline::run_sync(&mut e, &spec, 8, 42);
        t.push(
            &[bs.to_string()],
            &[
                r.eval_hit_rate(),
                r.eval_latencies().mean("e2e"),
                e.metrics.blocks_allocated as f64,
            ],
        );
    }
    t
}

/// Chunked-prefill token-budget sweep: queue vs decode trade-off for the
/// LoRA baseline (where prefill pressure exists).
pub fn chunk_budget_sweep() -> Table {
    let mut t = Table::new(
        "ablation-chunk-budget",
        "chunked-prefill budget vs eval queue/decode (LoRA baseline, prompt 8192)",
        &["budget", "queue(s)", "prefill(s)", "decode(s)", "e2e(s)"],
    );
    let spec = PipelineSpec::base_adapter(8192, 256, 16);
    for budget in [2048u32, 4096, 8192, 16384, 32768] {
        let mut cfg = presets::lora_baseline_of(presets::granite_8b());
        cfg.scheduler.max_batch_tokens = budget;
        let reg = workload::build_registry(1, cfg.model.vocab_size, false);
        let exec = SimExecutor::new(&cfg);
        let mut e = Engine::with_registry(cfg, reg, exec);
        let r = pipeline::run_sync(&mut e, &spec, 8, 42);
        let ev = r.eval_latencies();
        t.push(
            &[budget.to_string()],
            &[ev.mean("queue"), ev.mean("prefill"), ev.mean("decode"), ev.mean("e2e")],
        );
    }
    t
}

/// Prefix caching off: even aLoRA degenerates to the LoRA cost.
pub fn prefix_caching_ablation() -> Table {
    let mut t = Table::new(
        "ablation-prefix-caching",
        "automatic prefix caching on/off (aLoRA engine, prompt 4096)",
        &["prefix_caching", "hit_rate", "eval_e2e(s)"],
    );
    let spec = PipelineSpec::base_adapter(4096, 256, 16);
    for on in [true, false] {
        let mut e = engine_with(16, 8192, on, None);
        let r = pipeline::run_sync(&mut e, &spec, 8, 42);
        t.push(
            &[on.to_string()],
            &[r.eval_hit_rate(), r.eval_latencies().mean("e2e")],
        );
    }
    t
}

/// Capacity sweep: hit rate under eviction pressure (free-pool LRU).
pub fn capacity_sweep() -> Table {
    let mut t = Table::new(
        "ablation-capacity",
        "KV capacity vs async hit rate (prompt 256, gen 2048, rate 8/s)",
        &["kv_tokens", "hit_rate", "e2e_speedup_proxy(s)"],
    );
    let spec = PipelineSpec::base_adapter(256, 2048, 16);
    for kv in [8192u64, 16384, 65536, 351_104] {
        let mut e = engine_with(16, 8192, true, Some(kv));
        let r = pipeline::run_poisson(&mut e, &spec, 60, 8.0, 42);
        t.push(
            &[kv.to_string()],
            &[r.eval_hit_rate(), r.eval_latencies().mean("e2e")],
        );
    }
    t
}

/// Load-management sweep on the Figure-9 overflow scenario — the paper's
/// §4.3 "smart allocation" suggestion, implemented as two composable
/// mechanisms and ablated against vanilla:
///
/// 1. **priority continuations**: adapter evals / follow-up turns jump the
///    admission queue, harvesting their conversation's cached blocks
///    before newly arriving prefills evict them. (The big win.)
/// 2. **admission watermark**: defer admitting new conversations when
///    projected block demand exceeds a capacity fraction. (Incremental on
///    top.)
pub fn watermark_sweep() -> Table {
    let mut t = Table::new(
        "ablation-watermark",
        "load management on the overflow workload (16k cache, rate 8/s)",
        &["priority", "watermark", "hit_rate", "eval_e2e(s)", "preemptions"],
    );
    for (priority, wm) in
        [(false, 1.0f64), (true, 1.0), (true, 0.9), (true, 0.7), (true, 0.5)]
    {
        let mut spec = PipelineSpec::base_adapter(256, 2048, 16);
        spec.priority_continuations = priority;
        let mut cfg = presets::granite_8b();
        cfg.cache.max_kv_tokens = 16_384;
        cfg.scheduler.max_seq_len = 16_384;
        cfg.scheduler.admission_watermark = wm;
        let reg = workload::build_registry(1, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        let mut e = Engine::with_registry(cfg, reg, exec);
        let r = pipeline::run_poisson(&mut e, &spec, 60, 8.0, 42);
        t.push(
            &[priority.to_string(), format!("{wm}")],
            &[
                r.eval_hit_rate(),
                r.eval_latencies().mean("e2e"),
                e.metrics.requests_preempted as f64,
            ],
        );
    }
    t
}

pub fn run_all() -> Vec<Table> {
    vec![
        block_size_sweep(),
        chunk_budget_sweep(),
        prefix_caching_ablation(),
        capacity_sweep(),
        watermark_sweep(),
    ]
}

#[cfg(test)]
mod tests {
    #[test]
    fn prefix_caching_off_kills_hits() {
        let t = super::prefix_caching_ablation();
        let hits = t.col("hit_rate");
        assert!(hits[0] > 0.9 && hits[1] == 0.0, "{hits:?}");
        let e2e = t.col("eval_e2e(s)");
        assert!(e2e[0] < e2e[1]);
    }

    #[test]
    fn smaller_blocks_higher_hit_rate() {
        let t = super::block_size_sweep();
        let hits = t.col("hit_rate");
        // hit rate monotone non-increasing as blocks grow (coarser reuse)
        for w in hits.windows(2) {
            assert!(w[0] >= w[1] - 1e-9, "{hits:?}");
        }
        let allocs = t.col("blocks_alloc");
        assert!(allocs[0] > allocs[allocs.len() - 1], "{allocs:?}");
    }

    #[test]
    fn load_management_restores_reuse_under_overflow() {
        let t = super::watermark_sweep();
        let hits = t.col("hit_rate");
        // row 0 = vanilla (no priority, wm 1.0): reuse collapses under
        // overflow; priority continuations recover most of it, watermark
        // adds on top.
        assert!(hits[0] < 0.5, "vanilla should collapse: {hits:?}");
        let best = hits[1..].iter().cloned().fold(0.0f64, f64::max);
        assert!(best > 0.7, "load management should recover reuse: {hits:?}");
    }

    #[test]
    fn capacity_pressure_reduces_hits() {
        let t = super::capacity_sweep();
        let hits = t.col("hit_rate");
        assert!(
            hits[0] < hits[hits.len() - 1],
            "tight cache must hit less: {hits:?}"
        );
    }
}
