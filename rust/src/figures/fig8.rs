//! Figure 8: asynchronous base-adapter pipeline — evaluation-step
//! latencies vs Poisson arrival rate.
//!
//! Paper params: prompt 256, base gen 256, eval 16, 500 requests. Higher
//! arrival rates yield greater end-to-end speedups (queue + decode savings
//! from higher GPU utilization), plateauing once compute saturates.

use crate::pipeline::PipelineSpec;

use super::{run_poisson_pair, Table};

pub const N_REQUESTS: usize = 500;

pub fn rates(quick: bool) -> Vec<f64> {
    if quick {
        vec![0.5, 4.0, 16.0]
    } else {
        vec![0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0]
    }
}

pub fn run(quick: bool) -> Table {
    let n = if quick { 120 } else { N_REQUESTS };
    let mut t = Table::new(
        "fig8",
        &format!("async base-adapter eval latencies vs arrival rate (n={n})"),
        &[
            "rate(req/s)",
            "variant",
            "e2e(s)",
            "queue(s)",
            "prefill(s)",
            "decode(s)",
            "e2e_speedup",
        ],
    );
    let spec = PipelineSpec::base_adapter(256, 256, 16);
    for &rate in &rates(quick) {
        let pair = run_poisson_pair("granite-8b", &spec, n, rate, 42);
        let a = pair.alora.eval_latencies();
        let l = pair.lora.eval_latencies();
        let speedup = l.mean("e2e") / a.mean("e2e");
        for (name, r) in [("aLoRA", &a), ("LoRA", &l)] {
            t.push(
                &[format!("{rate}"), name.to_string()],
                &[
                    r.mean("e2e"),
                    r.mean("queue"),
                    r.mean("prefill"),
                    r.mean("decode"),
                    speedup,
                ],
            );
        }
    }
    t
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig8_speedup_grows_with_rate() {
        let t = super::run(true);
        let sp = t.col("e2e_speedup");
        // rows come in (aLoRA, LoRA) pairs with identical speedup values
        let per_rate: Vec<f64> = sp.chunks(2).map(|c| c[0]).collect();
        assert!(per_rate.iter().all(|&x| x > 1.0), "{per_rate:?}");
        assert!(
            per_rate.last().unwrap() > per_rate.first().unwrap(),
            "speedup should grow with arrival rate: {per_rate:?}"
        );
    }
}
