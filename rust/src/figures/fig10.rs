//! Figure 10: base-adapter-base pipeline, generation-length sweep +
//! the 5-parallel-adapter variant (§4.4, §4.4.1).
//!
//! Top row: varying the FIRST base call's generation length produces the
//! same speedups as varying prompt length (prefix caching doesn't
//! distinguish prefilled from generated blocks). Bottom row: with LoRA,
//! the long adapter prefills queue up and delay the SECOND base call's
//! TTFT — queuing damage propagates down the pipeline.

use crate::adapter::AdapterId;
use crate::pipeline::{PipelineKind, PipelineSpec};

use super::{run_sync_pair, Table};

pub fn gen_sweep(quick: bool) -> Vec<u32> {
    if quick {
        vec![256, 4096]
    } else {
        vec![256, 1024, 4096, 16384, 32768]
    }
}

fn spec(gen: u32, n_adapters: usize) -> PipelineSpec {
    PipelineSpec {
        kind: if n_adapters > 1 { PipelineKind::MultiAdapter } else { PipelineKind::BaseAdapterBase },
        prompt_len: 256,
        base_gen: gen,
        eval_gen: 16,
        adapters: (0..n_adapters as u32).map(AdapterId).collect(),
        base2_gen: 16,
        priority_continuations: false,
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let mut eval_t = Table::new(
        "fig10-eval",
        "base-adapter-base: eval-step latencies vs first-base generation length",
        &["gen_len", "variant", "e2e(s)", "queue(s)", "prefill(s)", "decode(s)", "e2e_speedup"],
    );
    let mut base2_t = Table::new(
        "fig10-base2",
        "base-adapter-base: second base call TTFT/queue (LoRA queuing damage)",
        &["gen_len", "variant", "ttft(s)", "queue(s)", "e2e(s)"],
    );

    for &gen in &gen_sweep(quick) {
        let sp = spec(gen, 1);
        let cfg = crate::config::presets::granite_8b();
        let batch = crate::pipeline::workload::batch_size_for(
            &cfg,
            spec(*gen_sweep(quick).last().unwrap(), 1).max_total_len(),
        );
        let pair = run_sync_pair("granite-8b", &sp, batch, 42);
        let a = pair.alora.eval_latencies();
        let l = pair.lora.eval_latencies();
        let speedup = l.mean("e2e") / a.mean("e2e");
        for (name, r) in [("aLoRA", &a), ("LoRA", &l)] {
            eval_t.push(
                &[gen.to_string(), name.to_string()],
                &[r.mean("e2e"), r.mean("queue"), r.mean("prefill"), r.mean("decode"), speedup],
            );
        }
        let ab = pair.alora.base2_latencies();
        let lb = pair.lora.base2_latencies();
        for (name, r) in [("aLoRA", &ab), ("LoRA", &lb)] {
            base2_t.push(
                &[gen.to_string(), name.to_string()],
                &[r.mean("ttft"), r.mean("queue"), r.mean("e2e")],
            );
        }
    }

    // 5-adapter variant (fixed sizes per §4.4.1).
    let mut multi_t = Table::new(
        "fig10-multi",
        "5 parallel adapters: eval + consolidated base2 (prompt 256, gen 256)",
        &["variant", "eval_e2e(s)", "eval_hit", "base2_ttft(s)", "base2_queue(s)"],
    );
    let sp = spec(256, 5);
    let cfg = crate::config::presets::granite_8b();
    let batch = crate::pipeline::workload::batch_size_for(&cfg, sp.max_total_len());
    let pair = run_sync_pair("granite-8b", &sp, batch.min(32), 42);
    for (name, r) in [("aLoRA", &pair.alora), ("LoRA", &pair.lora)] {
        let ev = r.eval_latencies();
        let b2 = r.base2_latencies();
        multi_t.push(
            &[name.to_string()],
            &[ev.mean("e2e"), r.eval_hit_rate(), b2.mean("ttft"), b2.mean("queue")],
        );
    }

    vec![eval_t, base2_t, multi_t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig10_gen_length_behaves_like_prompt_length() {
        let tables = super::run(true);
        let sp = tables[0].col("e2e_speedup");
        let per_gen: Vec<f64> = sp.chunks(2).map(|c| c[0]).collect();
        assert!(per_gen.iter().all(|&x| x > 1.0), "{per_gen:?}");
        assert!(per_gen.last().unwrap() > per_gen.first().unwrap());
    }

    #[test]
    fn fig10_lora_queuing_hits_second_base_call() {
        let tables = super::run(true);
        let ttft = tables[1].col("ttft(s)");
        // rows per gen: aLoRA then LoRA; at the longest gen the LoRA
        // pipeline's base2 TTFT must exceed aLoRA's.
        let n = ttft.len();
        assert!(ttft[n - 1] > ttft[n - 2], "{ttft:?}");
    }

    #[test]
    fn fig10_multi_adapter_alora_wins() {
        let tables = super::run(true);
        let t = &tables[2];
        let e2e = t.col("eval_e2e(s)");
        assert!(e2e[0] < e2e[1], "aLoRA eval faster with 5 adapters");
        let hit = t.col("eval_hit");
        assert!(hit[0] > 0.8 && hit[1] == 0.0);
    }
}
