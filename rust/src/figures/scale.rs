//! `scale` figure + the million-session bench harness (ISSUE 6).
//!
//! Drives N concurrent conversations through a replica fleet under a
//! bursty diurnal-mixture Poisson arrival process and reports **tail**
//! latency (p50/p99 TTFT and ITL — means hide exactly the tail the
//! serving claims are about), per-turn placement cost in concrete ops
//! (block hashes + sketch probes at submit/complete time), and the peak
//! memory ceilings: in-use KV blocks, live sessions, and the bounded
//! metrics reservoirs. The `scale` figure (reachable via
//! `figure --id scale`, deliberately not part of `all`) runs a shrunk
//! two-point grid whose money shape is the placement-cost column staying
//! FLAT as the session table grows; `bench_scale` runs the same harness
//! at 10^5 (`--quick`) / 10^6 sessions and writes `BENCH_scale.json`.

use super::Table;
use crate::adapter::AdapterId;
use crate::cluster::{Cluster, RoutePolicy};
use crate::config::presets;
use crate::engine::{Engine, EngineDriver};
use crate::kvcache::{prefix, summary};
use crate::pipeline::workload;
use crate::request::session::SessionId;
use crate::request::{ModelTarget, RequestId};
use crate::session::SessionManager;
use crate::simulator::SimExecutor;
use crate::util::fxmap::FxHashMap;
use crate::util::rng::Rng;
use crate::util::stats::Samples;

/// One harness run's knobs. Token sizes are deliberately small: the
/// harness measures the *serving control plane* at scale (placement,
/// hashing, leases, expiry), not model compute.
#[derive(Debug, Clone)]
pub struct ScaleConfig {
    /// Concurrent conversations to ramp the session table up to.
    pub sessions: usize,
    /// Follow-up (delta) turns measured after the ramp.
    pub followups: usize,
    pub replicas: usize,
    /// Base arrival rate in turns per virtual second; the diurnal
    /// mixture multiplies it per day phase.
    pub arrival_rate: f64,
    /// Admission throttle: max turns in flight across the fleet.
    pub max_in_flight: usize,
    /// First-turn prompt length (tokens).
    pub first_len: usize,
    /// Follow-up delta length (tokens).
    pub delta_len: usize,
    pub gen_tokens: u32,
    /// Idle TTL handed to the SessionManager; the end-of-run sweep
    /// advances past it and must collapse the table to zero.
    pub idle_ttl: f64,
    pub seed: u64,
}

impl ScaleConfig {
    /// Shared shape; only the session count scales between tiers.
    pub fn sized(sessions: usize) -> Self {
        ScaleConfig {
            sessions,
            followups: sessions / 4,
            replicas: 4,
            arrival_rate: 256.0,
            max_in_flight: 512,
            first_len: 64,
            delta_len: 16,
            gen_tokens: 4,
            idle_ttl: 3600.0,
            seed: 0x5CA1E,
        }
    }

    /// `bench_scale --quick`: 10^5 concurrent sessions.
    pub fn quick_bench() -> Self {
        Self::sized(100_000)
    }

    /// `bench_scale` full tier: 10^6 concurrent sessions.
    pub fn full_bench() -> Self {
        Self::sized(1_000_000)
    }
}

/// What one harness run measured.
#[derive(Debug)]
pub struct ScaleReport {
    pub sessions: usize,
    pub turns: u64,
    pub ttft: Samples,
    pub itl: Samples,
    /// Block-hash / sketch-probe ops spent at submit + complete time
    /// (placement, chain extension, lease advance). Decode-side
    /// generation hashing is excluded — that is compute, not placement.
    pub hash_ops: u64,
    pub probe_ops: u64,
    pub peak_sessions: usize,
    /// Fleet-wide peak of in-use KV blocks.
    pub peak_blocks: u64,
    /// Total retained latency samples across every replica's per-turn
    /// reservoirs — the bounded-metrics memory ceiling.
    pub metrics_retained: usize,
    pub expired: u64,
    pub final_sessions: usize,
    /// Virtual seconds the measured workload spanned (pre-expiry).
    pub virtual_s: f64,
}

impl ScaleReport {
    pub fn hash_ops_per_turn(&self) -> f64 {
        self.hash_ops as f64 / self.turns.max(1) as f64
    }

    pub fn probe_ops_per_turn(&self) -> f64 {
        self.probe_ops as f64 / self.turns.max(1) as f64
    }
}

/// Diurnal mixture over a 60-virtual-second "day": night lull, daytime
/// baseline, evening burst. Mean multiplier ≈ 1.33, peak 2.65× — the
/// bursts are what push queueing into the p99.
fn diurnal_rate(base: f64, t: f64) -> f64 {
    const DAY_S: f64 = 60.0;
    let phase = ((t / (DAY_S / 3.0)) as usize) % 3;
    base * [0.35, 1.0, 2.65][phase]
}

/// Run the harness: ramp `sessions` conversations into the table, then
/// `followups` delta turns against it (every 8th an aLoRA invocation
/// branch), all under the arrival process and the in-flight throttle;
/// finish with a TTL sweep that must empty the table.
pub fn run_harness(cfg: &ScaleConfig) -> ScaleReport {
    let vocab = presets::granite_8b().model.vocab_size;
    let mut c = Cluster::from_factory(cfg.replicas, RoutePolicy::PrefixAffinity, |_| {
        let e_cfg = presets::granite_8b();
        let reg = workload::build_registry(2, e_cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&e_cfg);
        Engine::with_registry(e_cfg, reg, exec)
    })
    .expect("cluster construction");
    let mgr = SessionManager::with_limits(Some(cfg.idle_ttl), None, None);
    let mut rng = Rng::new(cfg.seed);
    let total = cfg.sessions + cfg.followups;
    let mut in_flight: FxHashMap<RequestId, SessionId> = FxHashMap::default();
    let mut parked: Vec<SessionId> = Vec::with_capacity(cfg.sessions);
    let (mut ttft, mut itl) = (Samples::new(), Samples::new());
    let (mut hash_ops, mut probe_ops) = (0u64, 0u64);
    let (mut begun, mut completed) = (0usize, 0u64);
    let (mut peak_sessions, mut peak_blocks) = (0usize, 0u64);
    let mut next_t = rng.exponential(cfg.arrival_rate);
    // Drain the thread-local counters so earlier work on this thread is
    // not billed to the harness.
    let _ = prefix::take_hash_ops();
    let _ = summary::take_probe_ops();
    while completed < total as u64 {
        // Admit every due arrival the throttle allows.
        while begun < total && in_flight.len() < cfg.max_in_flight && next_t <= c.clock() {
            let (sid, target, delta, append) = if begun < cfg.sessions {
                // Ramp: a brand-new conversation's first turn.
                let sid = mgr.create_at(0, c.clock());
                let prompt = workload::prompt(&mut rng, cfg.first_len, vocab);
                (sid, ModelTarget::Base, prompt, true)
            } else {
                // Steady state: a delta turn on a random parked
                // conversation.
                if parked.is_empty() {
                    break; // everything is mid-turn; wait for completions
                }
                let i = rng.next_below(parked.len() as u64) as usize;
                let sid = parked.swap_remove(i);
                if begun % 8 == 7 {
                    // aLoRA invocation branch over the conversation
                    // (append=false): the paper's cross-model reuse.
                    let inv = workload::invocation_for(vocab, 0);
                    (sid, ModelTarget::Adapter(AdapterId(0)), inv, false)
                } else {
                    let delta = workload::prompt(&mut rng, cfg.delta_len, vocab);
                    (sid, ModelTarget::Base, delta, true)
                }
            };
            let (_turn, rid) = mgr
                .begin_turn(&mut c, sid, target, delta, cfg.gen_tokens, append)
                .expect("scale harness submission");
            hash_ops += prefix::take_hash_ops();
            probe_ops += summary::take_probe_ops();
            in_flight.insert(rid, sid);
            begun += 1;
            next_t += rng.exponential(diurnal_rate(cfg.arrival_rate, next_t));
        }
        peak_sessions = peak_sessions.max(mgr.len());
        if in_flight.is_empty() {
            // Idle gap before the next arrival: jump the virtual clock.
            c.advance_clock_to(next_t);
            continue;
        }
        if !c.step() {
            panic!("scale harness stalled with {} turns in flight", in_flight.len());
        }
        // Decode-side hashing (committed generation blocks) is compute,
        // not placement: drain it out of the placement counters.
        let _ = prefix::take_hash_ops();
        let _ = summary::take_probe_ops();
        for out in c.take_finished() {
            if let Some(sid) = in_flight.remove(&out.id) {
                let rec = mgr.complete_turn(&mut c, sid, &out).expect("turn completion");
                hash_ops += prefix::take_hash_ops();
                probe_ops += summary::take_probe_ops();
                ttft.push(rec.ttft_s);
                itl.push(rec.itl_s);
                parked.push(sid);
                completed += 1;
                if completed % 1024 == 0 {
                    let used: u64 = (0..c.num_replicas())
                        .map(|i| {
                            let r = c.replica(i);
                            (r.num_total_blocks() - r.num_free_blocks()) as u64
                        })
                        .sum();
                    peak_blocks = peak_blocks.max(used);
                }
            }
        }
    }
    let virtual_s = c.clock();
    // TTL sweep: everything is parked now; advancing past the TTL must
    // collapse the table to zero and release every lease.
    let horizon = c.clock() + cfg.idle_ttl * 2.0;
    c.advance_clock_to(horizon);
    let expired = mgr.expire_idle(&mut c).len() as u64;
    let metrics_retained: usize = (0..c.num_replicas())
        .map(|i| {
            let t = &c.replica(i).metrics().turn;
            t.e2e.retained()
                + t.queue.retained()
                + t.prefill.retained()
                + t.decode.retained()
                + t.ttft.retained()
                + t.itl.retained()
                + t.inference.retained()
        })
        .sum();
    ScaleReport {
        sessions: cfg.sessions,
        turns: completed,
        ttft,
        itl,
        hash_ops,
        probe_ops,
        peak_sessions,
        peak_blocks,
        metrics_retained,
        expired,
        final_sessions: mgr.len(),
        virtual_s,
    }
}

/// The `scale` figure: a two-point session-count grid. The acceptance
/// shape: per-turn placement cost (hash + probe ops) and the metrics
/// ceiling stay FLAT while the session table grows 4×, and the p99
/// columns stay finite under the bursty arrivals.
pub fn run(quick: bool) -> Table {
    let sizes: &[usize] = if quick { &[1_000, 4_000] } else { &[10_000, 40_000] };
    let mut t = Table::new(
        "scale",
        "session-scale harness: tail latency + placement cost vs table size",
        &[
            "sessions",
            "turns",
            "ttft_p50_s",
            "ttft_p99_s",
            "itl_p50_s",
            "itl_p99_s",
            "hash_ops_turn",
            "probe_ops_turn",
            "peak_sessions",
            "peak_kv_blocks",
            "metrics_retained",
            "expired",
        ],
    );
    for &n in sizes {
        let mut r = run_harness(&ScaleConfig::sized(n));
        assert_eq!(r.final_sessions, 0, "TTL sweep left sessions behind");
        let row = [
            n as f64,
            r.turns as f64,
            r.ttft.percentile(50.0),
            r.ttft.p99(),
            r.itl.percentile(50.0),
            r.itl.p99(),
            r.hash_ops_per_turn(),
            r.probe_ops_per_turn(),
            r.peak_sessions as f64,
            r.peak_blocks as f64,
            r.metrics_retained as f64,
            r.expired as f64,
        ];
        t.push(&[], &row);
    }
    t
}
