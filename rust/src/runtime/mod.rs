//! PJRT runtime: load + execute the AOT-compiled model from rust (L3→L2
//! bridge). Python never runs here — the artifact directory produced by
//! `make artifacts` is the only interface:
//!
//! - `tiny_step.hlo.txt` — HLO *text* of the jitted `step` function
//!   (weights baked in). Text, not serialized proto: jax ≥ 0.5 emits
//!   64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//!   parser reassigns ids (see aot_recipe / xla-example README).
//! - `manifest.json` — shapes + argument order + invocation sequences.
//! - `golden.json` — scripted scenario for the integration tests.
//!
//! [`TinyModel::step`] is the functional KV-in/KV-out contract described
//! in DESIGN.md §9: one executable serves fresh prefill, cache-extension
//! prefill (cross-model reuse) and decode.

pub mod executor;
pub mod sampler;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

pub use executor::RealExecutor;

/// Parsed `manifest.json` — the contract between aot.py and this runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq_len: usize,
    pub block_size: usize,
    pub n_adapters: usize,
    pub invocation_tokens: Vec<Vec<u32>>,
}

impl Manifest {
    pub fn parse(j: &Json) -> Result<Manifest> {
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_u64)
                .map(|x| x as usize)
                .ok_or_else(|| anyhow::anyhow!("manifest key `{k}` missing or not an int"))
        };
        let invocation_tokens = j
            .get("invocation_tokens")
            .and_then(Json::as_arr)
            .context("invocation_tokens")?
            .iter()
            .map(|a| a.u32_vec().context("invocation token row"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            vocab_size: get("vocab_size")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            max_seq_len: get("max_seq_len")?,
            block_size: get("block_size")?,
            n_adapters: get("n_adapters")?,
            invocation_tokens,
        })
    }

    /// Flat element count of one KV tensor [L, S, H, Dh].
    pub fn kv_elems(&self) -> usize {
        self.n_layers * self.max_seq_len * self.n_heads * self.head_dim
    }

    /// Elements per (layer, token) slice — the granularity block copies
    /// move at: H × Dh.
    pub fn token_elems(&self) -> usize {
        self.n_heads * self.head_dim
    }
}

/// A KV tensor pair ([L, S, H, Dh] row-major f32). Owned by the executor
/// per in-flight request; block contents are copied in/out of the shared
/// block store around each step.
#[derive(Debug, Clone)]
pub struct KvBuf {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl KvBuf {
    pub fn zeros(m: &Manifest) -> Self {
        KvBuf { k: vec![0.0; m.kv_elems()], v: vec![0.0; m.kv_elems()] }
    }
}

/// The loaded PJRT executable + metadata.
///
/// Compiled in two variants: with the `real-runtime` feature this wraps a
/// real `xla` PJRT executable; without it (the offline default — the xla
/// bindings are not on the offline mirror, DESIGN.md §7/§9) an
/// API-compatible stub is built whose `load`/`step` return errors, so
/// every caller (CLI `serve --real`, examples, integration tests) still
/// compiles and skips/fails cleanly at runtime.
#[cfg(feature = "real-runtime")]
pub struct TinyModel {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    kv_dims: [i64; 4],
}

/// Offline stub (see the `real-runtime` variant above).
#[cfg(not(feature = "real-runtime"))]
pub struct TinyModel {
    pub manifest: Manifest,
}

impl TinyModel {
    /// Default artifact directory: `$ALORA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ALORA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn artifacts_present(dir: &Path) -> bool {
        dir.join("tiny_step.hlo.txt").exists() && dir.join("manifest.json").exists()
    }
}

#[cfg(not(feature = "real-runtime"))]
impl TinyModel {
    pub fn load(dir: &Path) -> Result<TinyModel> {
        anyhow::bail!(
            "real PJRT runtime unavailable: built without the `real-runtime` \
             feature (requires the external `xla` crate; see DESIGN.md §9). \
             Artifacts dir: {}",
            dir.display()
        )
    }

    pub fn step(
        &self,
        _tokens: &[u32],
        _kv: &KvBuf,
        _start: usize,
        _length: usize,
        _mask_pre: &[bool],
        _adapter_onehot: &[f32],
    ) -> Result<(Vec<f32>, KvBuf)> {
        anyhow::bail!("real PJRT runtime unavailable (built without `real-runtime`)")
    }
}

#[cfg(feature = "real-runtime")]
impl TinyModel {
    /// Load artifacts from a directory.
    pub fn load(dir: &Path) -> Result<TinyModel> {
        let manifest = Manifest::parse(
            &Json::parse_file(&dir.join("manifest.json")).context("manifest.json")?,
        )?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let hlo_path = dir.join("tiny_step.hlo.txt");
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .with_context(|| format!("parsing {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        let kv_dims = [
            manifest.n_layers as i64,
            manifest.max_seq_len as i64,
            manifest.n_heads as i64,
            manifest.head_dim as i64,
        ];
        Ok(TinyModel { exe, manifest, kv_dims })
    }

    /// One forward step. See python/compile/model.py for the contract:
    /// computes K/V for positions [start, length), passes everything else
    /// through, returns logits at `length - 1`.
    ///
    /// `mask_pre[t] = true` ⇒ token t uses frozen base weights (pre-
    /// activation). `adapter_onehot` selects a baked adapter (all-zero =
    /// base model).
    pub fn step(
        &self,
        tokens: &[u32],
        kv: &KvBuf,
        start: usize,
        length: usize,
        mask_pre: &[bool],
        adapter_onehot: &[f32],
    ) -> Result<(Vec<f32>, KvBuf)> {
        let m = &self.manifest;
        anyhow::ensure!(tokens.len() <= m.max_seq_len, "token stream too long");
        anyhow::ensure!(length <= m.max_seq_len && start < length.max(1));
        anyhow::ensure!(mask_pre.len() == m.max_seq_len, "mask must be padded");
        anyhow::ensure!(adapter_onehot.len() == m.n_adapters);

        let mut tok_i32 = vec![0i32; m.max_seq_len];
        for (i, &t) in tokens.iter().enumerate() {
            tok_i32[i] = t as i32;
        }
        let mask_f32: Vec<f32> =
            mask_pre.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();

        let args = [
            xla::Literal::vec1(&tok_i32),
            xla::Literal::vec1(&kv.k).reshape(&self.kv_dims)?,
            xla::Literal::vec1(&kv.v).reshape(&self.kv_dims)?,
            xla::Literal::scalar(start as i32),
            xla::Literal::scalar(length as i32),
            xla::Literal::vec1(&mask_f32),
            xla::Literal::vec1(adapter_onehot),
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (logits_l, k_l, v_l) = result.to_tuple3()?;
        let logits = logits_l.to_vec::<f32>()?;
        anyhow::ensure!(logits.len() == m.vocab_size, "bad logits shape");
        let k = k_l.to_vec::<f32>()?;
        let v = v_l.to_vec::<f32>()?;
        Ok((logits, KvBuf { k, v }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let j = Json::parse(
            r#"{"vocab_size":512,"d_model":128,"n_layers":4,"n_heads":4,
                "head_dim":32,"max_seq_len":160,"block_size":16,
                "n_adapters":3,"rank":32,"invocation_len":4,
                "invocation_tokens":[[508,509,510,511],[504,505,506,507],[500,501,502,503]]}"#,
        )
        .unwrap();
        let m = Manifest::parse(&j).unwrap();
        assert_eq!(m.max_seq_len, 160);
        assert_eq!(m.kv_elems(), 4 * 160 * 4 * 32);
        assert_eq!(m.token_elems(), 128);
        assert_eq!(m.invocation_tokens[2], vec![500, 501, 502, 503]);
    }

    #[test]
    fn manifest_missing_key_errors() {
        let j = Json::parse(r#"{"vocab_size": 512}"#).unwrap();
        assert!(Manifest::parse(&j).is_err());
    }
}
