//! Token sampling over logits (greedy + temperature).

use crate::util::rng::Rng;

/// Greedy argmax (ties broken toward the lower token id).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u32
}

/// Temperature sampling via softmax + inverse-CDF draw.
pub fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let inv_t = 1.0 / temperature;
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut probs: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - m) * inv_t) as f64).exp())
        .collect();
    let z: f64 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= z;
    }
    let u = rng.next_f64();
    let mut acc = 0.0;
    for (i, p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i as u32;
        }
    }
    (probs.len() - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max_and_breaks_ties_low() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0, 5.0, 1.0]), 0);
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut rng = Rng::new(1);
        assert_eq!(sample(&[0.0, 9.0, 1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn sampling_respects_distribution() {
        // One dominant logit: sampled overwhelmingly often.
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 8.0, 0.0, 0.0];
        let hits = (0..500)
            .filter(|_| sample(&logits, 1.0, &mut rng) == 1)
            .count();
        assert!(hits > 480, "hits={hits}");
    }

    #[test]
    fn sampling_covers_uniform_support() {
        let mut rng = Rng::new(3);
        let logits = [1.0f32; 4];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, 1.0, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
