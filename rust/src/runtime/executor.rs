//! [`RealExecutor`]: the engine's executor backed by the PJRT-compiled
//! model, with physical KV block storage in rust.
//!
//! This is where cross-model cache reuse becomes *real data movement*: the
//! block manager's `BlockId`s key a store of actual K/V tensors. When the
//! scheduler admits a request whose hash chain hit cached blocks, this
//! executor gathers those blocks into the request's KV buffer — no model
//! execution happens for those tokens. After each step, freshly computed
//! full-or-partial blocks are scattered back into the store under the
//! request's block table, so the *base model's* blocks are byte-for-byte
//! the ones a later aLoRA request consumes (and vice versa).
//!
//! Sequences execute one PJRT call each (the tiny artifact is batch-1;
//! engine-level continuous batching is still exercised — chunking, masks,
//! admission — and the measured wall time per step feeds the same metrics
//! pipeline as the simulator's virtual time).

use crate::util::fxmap::FxHashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{BatchMask, Executor, StepResult};
use crate::kvcache::block::BlockId;
use crate::kvcache::manager::KvCacheManager;
use crate::request::{ModelTarget, Request, RequestId};
use crate::scheduler::ScheduledStep;
use crate::util::rng::Rng;

use super::sampler;
use super::{KvBuf, Manifest, TinyModel};

/// K/V contents of one physical block: [L, block_size, H, Dh] per tensor,
/// flattened.
#[derive(Debug, Clone)]
struct BlockData {
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct RealExecutor {
    model: TinyModel,
    /// Physical block store: BlockId -> tensor contents.
    store: FxHashMap<BlockId, BlockData>,
    /// Per-in-flight-request working KV buffers.
    bufs: FxHashMap<RequestId, KvBuf>,
    rng: Rng,
    /// Wall seconds spent inside PJRT execute (profiling).
    pub model_time: f64,
    /// Wall seconds spent on block gather/scatter (profiling).
    pub copy_time: f64,
    pub steps_executed: u64,
}

// SAFETY: the xla crate's PJRT wrappers hold `Rc` + raw pointers, making
// them !Send by default. The RealExecutor is only ever owned by one thread
// at a time (the engine, or the server's driver thread behind a Mutex);
// no Rc clone escapes this struct, and the PJRT CPU client itself is
// thread-compatible. Moving the whole executor between threads is
// therefore sound; concurrent *access* is prevented by the owning Mutex.
unsafe impl Send for RealExecutor {}

impl RealExecutor {
    pub fn load(artifacts_dir: &Path, seed: u64) -> Result<Self> {
        Ok(RealExecutor {
            model: TinyModel::load(artifacts_dir)?,
            store: FxHashMap::default(),
            bufs: FxHashMap::default(),
            rng: Rng::new(seed),
            model_time: 0.0,
            copy_time: 0.0,
            steps_executed: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.model.manifest
    }

    pub fn model(&self) -> &TinyModel {
        &self.model
    }

    fn block_elems(&self) -> usize {
        let m = &self.model.manifest;
        m.n_layers * m.block_size * m.token_elems()
    }

    /// Copy block `b_idx` (token rows [b_idx·bs, (b_idx+1)·bs)) of `buf`
    /// into the store under `bid`.
    fn scatter_block(&mut self, bid: BlockId, buf: &KvBuf, b_idx: usize) {
        let m = &self.model.manifest;
        let bs = m.block_size;
        let te = m.token_elems();
        let row = m.max_seq_len * te; // elems per layer in the buffer
        let mut data = BlockData {
            k: vec![0.0; self.block_elems()],
            v: vec![0.0; self.block_elems()],
        };
        for l in 0..m.n_layers {
            let src = l * row + b_idx * bs * te;
            let dst = l * bs * te;
            data.k[dst..dst + bs * te].copy_from_slice(&buf.k[src..src + bs * te]);
            data.v[dst..dst + bs * te].copy_from_slice(&buf.v[src..src + bs * te]);
        }
        self.store.insert(bid, data);
    }

    /// Copy the store contents of `bid` into block row `b_idx` of `buf`.
    fn gather_block(&self, bid: BlockId, buf: &mut KvBuf, b_idx: usize) {
        let m = &self.model.manifest;
        let bs = m.block_size;
        let te = m.token_elems();
        let row = m.max_seq_len * te;
        let data = self
            .store
            .get(&bid)
            .unwrap_or_else(|| panic!("cache-hit block {bid:?} missing from store"));
        for l in 0..m.n_layers {
            let dst = l * row + b_idx * bs * te;
            let src = l * bs * te;
            buf.k[dst..dst + bs * te].copy_from_slice(&data.k[src..src + bs * te]);
            buf.v[dst..dst + bs * te].copy_from_slice(&data.v[src..src + bs * te]);
        }
    }

    /// Ensure a working buffer exists for `r`, gathering any cache-hit
    /// blocks (chunk_start > 0 with no buffer = admission after hits or
    /// after preemption).
    fn ensure_buf(&mut self, r: &Request, kv: &KvCacheManager, chunk_start: usize) {
        if self.bufs.contains_key(&r.id) {
            return;
        }
        let m = &self.model.manifest;
        let mut buf = KvBuf::zeros(m);
        if chunk_start > 0 {
            let bs = m.block_size;
            debug_assert_eq!(chunk_start % bs, 0, "cached prefix is block-aligned");
            let blocks = kv.blocks_of(r.id.0);
            let t0 = Instant::now();
            for b_idx in 0..chunk_start / bs {
                self.gather_block(blocks[b_idx], &mut buf, b_idx);
            }
            self.copy_time += t0.elapsed().as_secs_f64();
        }
        self.bufs.insert(r.id, buf);
    }

    /// Drop working buffers for requests no longer tracked by the engine.
    fn gc(&mut self, reqs: &FxHashMap<RequestId, Request>) {
        self.bufs.retain(|id, _| reqs.contains_key(id));
    }

    /// Store usage (for tests / debugging).
    pub fn stored_blocks(&self) -> usize {
        self.store.len()
    }
}

impl Executor for RealExecutor {
    fn execute(
        &mut self,
        step: &ScheduledStep,
        reqs: &FxHashMap<RequestId, Request>,
        kv: &KvCacheManager,
        mask: &BatchMask,
    ) -> StepResult {
        let wall = Instant::now();
        let mut sampled = Vec::new();

        // Preempted requests lost their blocks; drop their working buffers
        // so re-admission regathers from whatever cache survives.
        for id in &step.preempted {
            self.bufs.remove(id);
        }

        for s in &step.seqs {
            let r = &reqs[&s.id];
            self.ensure_buf(r, kv, s.chunk_start);

            // Build the padded per-request mask from the batch mask span
            // plus the request's activation point for positions outside
            // this chunk (they matter because attention runs over the whole
            // window inside the artifact).
            let m = &self.model.manifest;
            let mut mask_pre = vec![false; m.max_seq_len];
            for (p, slot) in mask_pre.iter_mut().enumerate() {
                *slot = p < r.activation_start;
            }
            // Sanity: the batch-mask span agrees on this chunk.
            if let Some(span) = mask.span_of(s.id) {
                for (i, &pre) in span.iter().enumerate() {
                    debug_assert_eq!(pre, mask_pre[s.chunk_start + i]);
                }
            }

            let mut onehot = vec![0.0f32; m.n_adapters];
            if let ModelTarget::Adapter(aid) = r.target {
                let idx = aid.0 as usize;
                assert!(idx < m.n_adapters, "adapter {idx} not baked into artifact");
                onehot[idx] = 1.0;
            }

            let tokens = r.all_tokens();
            let length = s.chunk_start + s.chunk_len;
            let buf = self.bufs.get(&s.id).unwrap().clone();
            let t0 = Instant::now();
            let (logits, new_buf) = self
                .model
                .step(&tokens, &buf, s.chunk_start, length, &mask_pre, &onehot)
                .expect("model step failed");
            self.model_time += t0.elapsed().as_secs_f64();

            // Scatter back every block this chunk touched (full blocks may
            // be committed by the engine right after this call).
            let bs = m.block_size;
            let blocks = kv.blocks_of(s.id.0).to_vec();
            let first_b = s.chunk_start / bs;
            let last_b = (length - 1) / bs;
            let t1 = Instant::now();
            for b_idx in first_b..=last_b {
                self.scatter_block(blocks[b_idx], &new_buf, b_idx);
            }
            self.copy_time += t1.elapsed().as_secs_f64();
            self.bufs.insert(s.id, new_buf);

            if s.produces_token {
                let tok = if r.params.sample {
                    sampler::sample(&logits, r.params.temperature, &mut self.rng)
                } else {
                    sampler::argmax(&logits)
                };
                sampled.push((s.id, tok));
            }
        }

        self.gc(reqs);
        self.steps_executed += 1;
        StepResult { elapsed: wall.elapsed().as_secs_f64(), sampled }
    }
}
