//! Integration: hot-path scaling acceptance (ISSUE 6).
//!
//! Two pins, both against the live cluster serving path (SessionManager →
//! Cluster → Engine):
//!
//! - **Per-turn placement cost is O(delta + replicas)** — the block-hash
//!   ops and sketch-probe ops a delta turn spends (session chain
//!   extension, admission, decode, lease extension) are bounded by the
//!   turn's own size, INDEPENDENT of how long the conversation already
//!   is. Measured with the thread-local op counters the kvcache layer
//!   exports exactly for this test.
//! - **Routing is bit-identical** — the watermark/lease-hint scorer
//!   (`Cluster::views_for_chain`) places every request on exactly the
//!   replica the pre-overhaul full-scan scorer would have picked. The
//!   reference scorer is reimplemented here from first principles: full
//!   `matching_prefix` over every replica plus the router's published
//!   `affine_choose` semantics (strict-`>` argmax, first-index ties,
//!   cold fallback to least-loaded).

use alora_serve::adapter::AdapterId;
use alora_serve::cluster::{Cluster, ReplicaHealth, RoutePolicy};
use alora_serve::config::presets;
use alora_serve::engine::{Engine, EngineDriver};
use alora_serve::kvcache::chain;
use alora_serve::kvcache::prefix::{self, block_hashes, HashContext};
use alora_serve::kvcache::summary;
use alora_serve::pipeline::workload;
use alora_serve::request::ModelTarget;
use alora_serve::session::SessionManager;
use alora_serve::simulator::SimExecutor;
use alora_serve::util::rng::Rng;

const N_REPLICAS: usize = 3;
const N_ADAPTERS: u32 = 2;

fn sim_engine() -> Engine<SimExecutor> {
    let cfg = presets::granite_8b();
    let reg = workload::build_registry(N_ADAPTERS, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

fn cluster() -> Cluster<SimExecutor> {
    Cluster::from_factory(N_REPLICAS, RoutePolicy::PrefixAffinity, |_| sim_engine()).unwrap()
}

fn reset_op_counters() {
    let _ = prefix::take_hash_ops();
    let _ = summary::take_probe_ops();
}

// ---------------------------------------------------------------------------
// Op-counter acceptance: placement cost per turn.

/// Drive `turns` 64-token delta turns of one session over the cluster,
/// then measure the total op cost (block hashes, sketch probes) of ONE
/// more identical turn, end to end.
fn cost_after(turns: usize) -> (u64, u64) {
    let vocab = presets::granite_8b().model.vocab_size;
    let mut c = cluster();
    let mut mgr = SessionManager::new();
    let mut rng = Rng::new(0xC057);
    let sid = mgr.create(0);
    for _ in 0..turns {
        let delta = rng.tokens(64, vocab, workload::RESERVED_TOP);
        mgr.run_turn(&mut c, sid, ModelTarget::Base, delta, 8, true).unwrap();
    }
    let delta = rng.tokens(64, vocab, workload::RESERVED_TOP);
    reset_op_counters();
    mgr.run_turn(&mut c, sid, ModelTarget::Base, delta, 8, true).unwrap();
    (prefix::take_hash_ops(), summary::take_probe_ops())
}

#[test]
fn delta_turn_cost_is_independent_of_conversation_length() {
    let (h_short, p_short) = cost_after(4); // 4-turn history: 288 tokens
    let (h_long, p_long) = cost_after(12); // 3× the history: 864 tokens
    assert!(h_short > 0, "hash op counter is wired");
    assert!(p_short > 0, "probe op counter is wired");
    // O(delta): the turn adds 64 prompt + 8 generated tokens over
    // 16-token blocks — a handful of block hashes (chain extension) and
    // sketch probes (lease advance), with slack for boundary effects.
    // A full re-hash of even the SHORT conversation would already cost
    // 18+ ops; the long one 54+.
    let bound = (64 + 8) / 16 + 8;
    assert!(h_short <= bound, "short-history turn hashed {h_short} blocks (> {bound})");
    assert!(h_long <= bound, "long-history turn hashed {h_long} blocks (> {bound})");
    assert!(p_long <= bound, "long-history turn probed {p_long} slots (> {bound})");
    // Independence: tripling the conversation must not grow the
    // per-turn cost at all — the turns are structurally identical.
    assert!(
        h_long <= h_short,
        "hash ops grew with conversation length: {h_short} -> {h_long}"
    );
    assert!(
        p_long <= p_short,
        "probe ops grew with conversation length: {p_short} -> {p_long}"
    );
}

/// Drive `turns` 64-token delta turns of one session, then measure the
/// arena chain ops (node appends, full-chain materializations) of ONE
/// more identical turn, end to end.
fn chain_cost_after(turns: usize) -> (u64, u64) {
    let vocab = presets::granite_8b().model.vocab_size;
    let mut c = cluster();
    let mut mgr = SessionManager::new();
    let mut rng = Rng::new(0x0C0F);
    let sid = mgr.create(0);
    for _ in 0..turns {
        let delta = rng.tokens(64, vocab, workload::RESERVED_TOP);
        mgr.run_turn(&mut c, sid, ModelTarget::Base, delta, 8, true).unwrap();
    }
    let delta = rng.tokens(64, vocab, workload::RESERVED_TOP);
    let _ = chain::take_chain_ops();
    mgr.run_turn(&mut c, sid, ModelTarget::Base, delta, 8, true).unwrap();
    chain::take_chain_ops()
}

#[test]
fn delta_turn_makes_zero_full_chain_copies() {
    // The arena acceptance (ISSUE 7): a delta turn's chain work is
    // O(delta) node appends and ZERO full-chain materializations — the
    // `.to_vec()` copies the pre-arena code spent at every boundary
    // (session → router → engine → lease) are structurally gone, not
    // just cheaper. Counted with the thread-local chain-op counters the
    // arena exports exactly for this pin.
    let (a_short, c_short) = chain_cost_after(4); // 288 tokens of history
    let (a_long, c_long) = chain_cost_after(12); // 3× the history
    assert_eq!(c_short, 0, "short-history delta turn copied a full chain");
    assert_eq!(c_long, 0, "long-history delta turn copied a full chain");
    assert!(a_short > 0, "chain-op counter is wired");
    // Independence: tripling the conversation must not grow the per-turn
    // append count — the turns are structurally identical.
    assert!(
        a_long <= a_short,
        "arena appends grew with conversation length: {a_short} -> {a_long}"
    );
    // O(delta): the turn adds 64 prompt + 8 generated tokens over
    // 16-token blocks (≈5 blocks). A handful of chains advance per turn
    // (session, routing track, lease); even 4 of them re-appending the
    // delta stays far under the 54-block history a copy would touch.
    let bound = 4 * ((64 + 8) / 16 + 2) as u64;
    assert!(
        a_short <= bound,
        "delta turn appended {a_short} arena nodes (> {bound})"
    );
}

#[test]
fn first_turn_cost_is_delta_plus_replicas() {
    // A session's FIRST turn is all delta: it pays O(prompt) hashing
    // once plus O(replicas) routing probes on a cold fleet — never a
    // scan proportional to anything already cached elsewhere.
    let vocab = presets::granite_8b().model.vocab_size;
    let mut c = cluster();
    let mut mgr = SessionManager::new();
    let mut rng = Rng::new(0xF157);
    let sid = mgr.create(0);
    let prompt = rng.tokens(256, vocab, workload::RESERVED_TOP); // 16 blocks
    reset_op_counters();
    mgr.run_turn(&mut c, sid, ModelTarget::Base, prompt, 8, true).unwrap();
    let (h, p) = (prefix::take_hash_ops(), summary::take_probe_ops());
    let chain_blocks = 256 / 16;
    assert!(
        h <= (chain_blocks + 8) as u64,
        "first turn hashed {h} blocks for a {chain_blocks}-block prompt"
    );
    // Cold routing probes one slot per healthy replica (first miss),
    // plus the lease-advance probes over the turn's own chain.
    assert!(
        p <= (chain_blocks + N_REPLICAS + 8) as u64,
        "first turn probed {p} slots (chain {chain_blocks}, {N_REPLICAS} replicas)"
    );
}

// ---------------------------------------------------------------------------
// Routing bit-identity: watermark scorer vs full-scan reference.

/// The pre-overhaul scorer, from first principles: hash the prompt's
/// chain, run a FULL `matching_prefix` scan on every replica (no
/// watermark, no lease hint), then apply the router's exact
/// `PrefixAffinity` decision rule.
fn reference_placement(
    c: &Cluster<SimExecutor>,
    target: ModelTarget,
    prompt: &[u32],
    salt: u64,
) -> usize {
    let e0 = c.replica(0);
    let cfg = e0.config();
    let ctx = e0
        .registry()
        .request_hash_context(target.adapter(), prompt, cfg.cache.base_aligned_hashing, salt)
        .map(|(_, ctx)| ctx)
        .unwrap_or_else(|| HashContext { cache_salt: salt, ..HashContext::base() });
    let chain = block_hashes(prompt, cfg.cache.block_size as usize, &ctx);
    let penalty = c.router().load_penalty();
    // (load, value = full-scan prefix affinity + resident adapter pages,
    // healthy) per replica.
    let views: Vec<(usize, usize, bool)> = (0..c.num_replicas())
        .map(|i| {
            let r = c.replica(i);
            let load = r.num_running() + r.num_waiting();
            let aff =
                if chain.is_empty() { 0 } else { r.routing_summary().matching_prefix(&chain) };
            let ad = target.adapter().map(|a| r.adapter_affinity_blocks(a)).unwrap_or(0);
            (load, aff + ad, c.health(i) == ReplicaHealth::Up)
        })
        .collect();
    let best = views.iter().filter(|v| v.2).map(|v| v.1).max().unwrap_or(0);
    if best == 0 {
        // Cold fallback: least-loaded healthy, first index on ties.
        return views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.2)
            .min_by_key(|(_, v)| v.0)
            .map(|(i, _)| i)
            .expect("no healthy replicas");
    }
    let score = |v: &(usize, usize, bool)| v.1 as f64 - penalty * v.0 as f64;
    let mut pick = views.iter().position(|v| v.2).expect("no healthy replicas");
    let mut pick_score = score(&views[pick]);
    for (j, v) in views.iter().enumerate() {
        if v.2 {
            let sc = score(v);
            if sc > pick_score {
                pick = j;
                pick_score = sc;
            }
        }
    }
    pick
}

fn replica_of(rid: alora_serve::request::RequestId) -> usize {
    // Replicas stripe the request-id namespace: id % n IS the replica
    // (the same fact `FailoverReport::strands` relies on).
    rid.0 as usize % N_REPLICAS
}

#[test]
fn watermark_scorer_places_bit_identically_to_full_scan() {
    let vocab = presets::granite_8b().model.vocab_size;
    let mut c = cluster();
    let mut mgr = SessionManager::new();
    let mut rng = Rng::new(0x51DE);
    // Three shared-prefix families: later first turns are genuinely warm
    // on some replicas and cold on others, so the watermark's skip path
    // actually fires instead of degenerating to the full scan.
    let families: Vec<Vec<u32>> = (0..3u64)
        .map(|f| {
            let mut fr = rng.fork(f);
            fr.tokens(256, vocab, workload::RESERVED_TOP)
        })
        .collect();
    let mut sessions = Vec::new();
    let mut checked = 0;
    for i in 0..12u64 {
        // A fresh session's first turn: placed by the scorer. Mix in an
        // aLoRA target (invocation appended, paper-style) so the
        // adapter-residency term and the aLoRA hash context are
        // exercised too.
        let mut first = families[(i % 3) as usize].clone();
        first.extend(rng.tokens(64, vocab, workload::RESERVED_TOP));
        let target = if i % 4 == 3 {
            first.extend(workload::invocation_for(vocab, 0));
            ModelTarget::Adapter(AdapterId(0))
        } else {
            ModelTarget::Base
        };
        let predicted = reference_placement(&c, target, &first, 0);
        let sid = mgr.create(0);
        mgr.run_turn(&mut c, sid, target, first, 16, true).unwrap();
        let actual = replica_of(mgr.get(sid).unwrap().last_request.unwrap());
        assert_eq!(actual, predicted, "session {i}: first-turn placement diverged");
        sessions.push(sid);
        checked += 1;
        // A delta turn on an older session: sticky while its replica is
        // up, re-scored through the router when it is not.
        if i >= 3 {
            let old = sessions[i as usize - 3];
            let prev = replica_of(mgr.get(old).unwrap().last_request.unwrap());
            let delta = rng.tokens(48, vocab, workload::RESERVED_TOP);
            let predicted = if c.health(prev) == ReplicaHealth::Up {
                prev
            } else {
                let mut prompt = mgr.get(old).unwrap().tokens().to_vec();
                prompt.extend_from_slice(&delta);
                reference_placement(&c, ModelTarget::Base, &prompt, 0)
            };
            mgr.run_turn(&mut c, old, ModelTarget::Base, delta, 8, true).unwrap();
            let actual = replica_of(mgr.get(old).unwrap().last_request.unwrap());
            assert_eq!(actual, predicted, "session {i}: delta-turn placement diverged");
            checked += 1;
        }
        // Mid-stream drain: later placements exercise the
        // unhealthy-skip path and re-sticking through the scorer.
        if i == 7 {
            c.drain_replica(1).unwrap();
        }
        if i == 9 {
            c.restore_replica(1).unwrap();
        }
    }
    assert!(checked >= 20, "only {checked} placements compared");
    for i in 0..N_REPLICAS {
        c.replica(i).check_invariants().unwrap();
    }
}
