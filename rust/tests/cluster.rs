//! Integration: replica cluster with base-aligned cache-affinity routing.
//!
//! The acceptance bar: on one multi-turn multi-adapter request stream over
//! ≥2 replicas, `PrefixAffinity` routing must achieve a strictly higher
//! aggregate prefix hit-rate than `RoundRobin` — i.e. the paper's
//! cross-model KV reuse survives horizontal scale-out only with
//! cache-affinity placement.

use std::collections::HashMap;

use alora_serve::adapter::AdapterId;
use alora_serve::cluster::{Cluster, ReplicaHealth, RoutePolicy};
use alora_serve::config::presets;
use alora_serve::engine::{Engine, EngineDriver};
use alora_serve::pipeline::{self, workload, PipelineKind, PipelineSpec};
use alora_serve::request::session::SessionId;
use alora_serve::request::{ModelTarget, RequestId, RequestOutput, SamplingParams};
use alora_serve::session::SessionManager;
use alora_serve::simulator::SimExecutor;

const N_ADAPTERS: u32 = 3;

fn sim_engine() -> Engine<SimExecutor> {
    let cfg = presets::granite_8b();
    let reg = workload::build_registry(N_ADAPTERS, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

fn cluster(n: usize, policy: RoutePolicy) -> Cluster<SimExecutor> {
    Cluster::from_factory(n, policy, |_| sim_engine()).unwrap()
}

/// Multi-turn multi-adapter conversation: base draft → 3 adapter evals →
/// consolidated base call. Every non-root stage extends the draft's token
/// stream, so its prefix hits iff it lands on the draft's replica.
fn multi_turn_spec() -> PipelineSpec {
    PipelineSpec {
        kind: PipelineKind::MultiAdapter,
        prompt_len: 1024,
        base_gen: 64,
        eval_gen: 16,
        adapters: (0..N_ADAPTERS).map(AdapterId).collect(),
        base2_gen: 16,
        priority_continuations: false,
    }
}

fn run_policy(policy: RoutePolicy, replicas: usize) -> (f64, Cluster<SimExecutor>) {
    let mut c = cluster(replicas, policy);
    // Same seed → bit-identical prompt stream and arrival times across
    // policies; only placement differs.
    let r = pipeline::run_poisson(&mut c, &multi_turn_spec(), 24, 8.0, 42);
    assert_eq!(r.outputs.len(), 24 * 5, "all stages completed");
    let hit = c.aggregate_hit_rate();
    (hit, c)
}

#[test]
fn prefix_affinity_beats_round_robin_on_same_stream() {
    let (hit_affinity, ca) = run_policy(RoutePolicy::PrefixAffinity, 2);
    let (hit_rr, _) = run_policy(RoutePolicy::RoundRobin, 2);
    assert!(
        hit_affinity > hit_rr,
        "affinity hit-rate {hit_affinity:.3} must strictly beat round-robin {hit_rr:.3}"
    );
    // And not vacuously: the warm stream really reuses prefixes.
    assert!(hit_affinity > 0.3, "affinity hit-rate collapsed: {hit_affinity:.3}");
    // 4 follow-up stages per conversation had a warm replica to find.
    let stats = &ca.router().stats;
    assert!(stats.affinity_hits > 0, "no warm placements recorded");
    assert_eq!(
        stats.total_routed(),
        24 * 5,
        "every stage went through the router"
    );
}

#[test]
fn affinity_gap_widens_with_more_replicas() {
    // Round-robin spreads a conversation's follow-ups over N replicas, so
    // its hit-rate decays with N while affinity's holds roughly flat.
    let (aff2, _) = run_policy(RoutePolicy::PrefixAffinity, 2);
    let (aff4, _) = run_policy(RoutePolicy::PrefixAffinity, 4);
    let (rr4, _) = run_policy(RoutePolicy::RoundRobin, 4);
    assert!(aff4 > rr4, "affinity {aff4:.3} vs rr {rr4:.3} at 4 replicas");
    assert!(
        aff4 > 0.5 * aff2,
        "affinity should not collapse with scale: {aff2:.3} -> {aff4:.3}"
    );
}

#[test]
fn coordinator_children_inherit_parent_replica() {
    // Drive conversations over a 3-replica cluster and check placement by
    // its observable consequence: every follow-up stage hits at least its
    // conversation's full 1024-token prompt from cache. Prompts are unique
    // per conversation, so that is only possible on the replica that
    // served the draft — the child inherited its parent's placement.
    let mut c = cluster(3, RoutePolicy::PrefixAffinity);
    let r = pipeline::run_poisson(&mut c, &multi_turn_spec(), 9, 6.0, 7);
    let follow_ups: Vec<_> = r
        .outputs
        .iter()
        .filter(|(s, _)| !matches!(s, pipeline::Stage::Base1))
        .collect();
    assert_eq!(follow_ups.len(), 9 * 4);
    for (stage, out) in &follow_ups {
        assert!(
            out.num_cached_tokens >= 1024,
            "{stage:?} ({:?}) re-prefilled on a cold replica: {} cached",
            out.id,
            out.num_cached_tokens
        );
    }
    assert!(!c.has_work());
}

#[test]
fn cluster_deterministic_across_runs() {
    let run = || {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let r = pipeline::run_poisson(&mut c, &multi_turn_spec(), 8, 4.0, 21);
        (r.makespan, c.aggregate_hit_rate(), c.router().stats.routed.clone())
    };
    assert_eq!(run(), run());
}

// ---------------------------------------------------------------------------
// ISSUE-5 failover acceptance: kill a replica mid-conversation.

/// Drain one round of turns to completion and apply them; returns the
/// per-turn outputs keyed by request id.
fn drain_round(
    c: &mut Cluster<SimExecutor>,
    mgr: &mut SessionManager,
    pending: &[(SessionId, RequestId)],
) -> HashMap<RequestId, RequestOutput> {
    let mut outs: HashMap<RequestId, RequestOutput> = HashMap::new();
    loop {
        for o in c.take_finished() {
            outs.insert(o.id, o);
        }
        if pending.iter().all(|(_, rid)| outs.contains_key(rid)) {
            break;
        }
        assert!(c.step(), "cluster stalled with turns outstanding");
    }
    for (sid, rid) in pending {
        let out = outs.get(rid).expect("drained above");
        mgr.complete_turn(c, *sid, out).expect("turn completion");
    }
    outs
}

#[test]
fn failover_mid_conversation_loses_nothing_and_resticks_sessions() {
    // 4 replicas, 12 sticky sessions (3 per replica under least-loaded
    // first-turn placement). Replica 2 dies while every session's second
    // turn is in flight.
    let mut c = cluster(4, RoutePolicy::PrefixAffinity);
    let mut mgr = SessionManager::new();
    let sessions: Vec<SessionId> = (0..12).map(|_| mgr.create(0)).collect();

    // Round 0: open every conversation (cold), then round 1 warms it.
    for round in 0..2u32 {
        let mut pending = Vec::new();
        for (si, &sid) in sessions.iter().enumerate() {
            let base = (si as u32 + 1) * 10_000 + round * 100;
            let delta: Vec<u32> = if round == 0 {
                (base..base + 256).collect()
            } else {
                (base..base + 32).collect()
            };
            let (_t, rid) = mgr
                .begin_turn(&mut c, sid, ModelTarget::Base, delta, 16, true)
                .unwrap();
            pending.push((sid, rid));
        }
        drain_round(&mut c, &mut mgr, &pending);
    }
    assert_eq!(c.router().stats.sticky_routed, 12, "round 1 all sticky");

    // Round 2: submit everywhere, step mid-prefill, kill replica 2.
    let victim = 2usize;
    let mut pending = Vec::new();
    for (si, &sid) in sessions.iter().enumerate() {
        let base = (si as u32 + 1) * 10_000 + 200;
        let (_t, rid) = mgr
            .begin_turn(&mut c, sid, ModelTarget::Base, (base..base + 32).collect(), 16, true)
            .unwrap();
        pending.push((sid, rid));
    }
    for _ in 0..3 {
        c.step();
    }
    let victim_sessions: Vec<SessionId> = sessions
        .iter()
        .copied()
        .filter(|sid| {
            let peer = mgr.get(*sid).unwrap().last_request.unwrap();
            (peer.0 % 4) as usize == victim
        })
        .collect();
    assert!(!victim_sessions.is_empty(), "victim replica served no sessions");
    let report = c.fail_replica(victim).unwrap();
    assert!(report.requeued > 0, "mid-burst work was in flight");
    assert!(report.rejected.is_empty(), "identical survivors accept everything");
    mgr.repair_after_failover(&mut c, &report);
    assert_eq!(c.health(victim), ReplicaHealth::Down);

    // (a) Every submitted request still finishes, under its original id.
    let outs = drain_round(&mut c, &mut mgr, &pending);
    assert_eq!(outs.len(), pending.len(), "zero lost requests");
    // The victim's sessions recomputed their chains on survivors
    // (observable as recomputed tokens, not an error).
    for &sid in &victim_sessions {
        let rec = mgr.get(sid).unwrap().turns().last().unwrap().clone();
        assert_eq!(rec.cached_tokens, 0, "requeued turn re-prefilled cold");
    }

    // (b) The next turn succeeds and re-sticks on a survivor: the
    // requeued turn's completion re-homed the conversation, so turn 3 is
    // sticky AND warm.
    let sticky_before = c.router().stats.sticky_routed;
    let mut pending = Vec::new();
    for (si, &sid) in sessions.iter().enumerate() {
        let base = (si as u32 + 1) * 10_000 + 300;
        let (_t, rid) = mgr
            .begin_turn(&mut c, sid, ModelTarget::Base, (base..base + 32).collect(), 16, true)
            .unwrap();
        pending.push((sid, rid));
    }
    drain_round(&mut c, &mut mgr, &pending);
    assert_eq!(
        c.router().stats.sticky_routed - sticky_before,
        12,
        "every session re-stuck (survivor-homed peers are healthy)"
    );
    for &sid in &victim_sessions {
        let s = mgr.get(sid).unwrap();
        let home = (s.last_request.unwrap().0 % 4) as usize;
        assert_ne!(home, victim, "session re-homed off the dead replica");
        let rec = s.turns().last().unwrap();
        assert!(rec.cached_tokens > 256, "re-stuck turn warm: {}", rec.cached_tokens);
    }

    // (c) Invariants hold on every survivor (and the wiped victim).
    for sid in sessions {
        mgr.delete(&mut c, sid).unwrap();
    }
    for i in 0..4 {
        c.replica(i).check_invariants().unwrap();
    }
    assert_eq!(c.replica(victim).routing_summary().committed_blocks(), 0);
}

#[test]
fn drain_finishes_in_flight_conversations_before_exclusion() {
    // (d) drain: in-flight work on the draining replica completes there;
    // only NEW placements are excluded.
    let mut c = cluster(2, RoutePolicy::PrefixAffinity);
    let mut mgr = SessionManager::new();
    let sid = mgr.create(0);
    let (_t, rid) = mgr
        .begin_turn(&mut c, sid, ModelTarget::Base, (0..256).collect(), 16, true)
        .unwrap();
    c.step(); // prefill under way
    let home = (rid.0 % 2) as usize;
    c.drain_replica(home).unwrap();
    assert_eq!(c.health(home), ReplicaHealth::Draining);
    // The in-flight turn completes ON the draining replica.
    let outs = drain_round(&mut c, &mut mgr, &[(sid, rid)]);
    assert!(outs.contains_key(&rid));
    assert_eq!(c.replica(home).metrics.requests_finished, 1);
    // New traffic avoids it; the session's next turn re-sticks elsewhere.
    let one_shot = c
        .submit(
            ModelTarget::Base,
            vec![7; 64],
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        )
        .unwrap();
    assert_ne!((one_shot.0 % 2) as usize, home, "new work excluded from drain");
    let t2 = mgr
        .run_turn(&mut c, sid, ModelTarget::Base, (900..932).collect(), 8, true)
        .unwrap();
    assert_eq!(c.router().stats.resticks, 1);
    assert_eq!(t2.cached_tokens, 0, "re-stuck cold off the draining replica");
    c.run_until_idle();
    // Restore returns it to rotation with its cache intact (drain wipes
    // nothing).
    c.restore_replica(home).unwrap();
    assert!(c.replica(home).routing_summary().committed_blocks() > 0);
    mgr.delete(&mut c, sid).unwrap();
    c.replica(0).check_invariants().unwrap();
    c.replica(1).check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// ISSUE-9 self-driving fleet: detection edge cases via the public API.

#[test]
fn suspected_replica_recovering_mid_burst_loses_nothing_and_keeps_leases() {
    // A replica that misses enough beats to be Suspected — but resumes
    // before the down threshold — must lose no requests, keep its
    // sessions' leases, and stay sticky-routable.
    let mut c = cluster(2, RoutePolicy::PrefixAffinity);
    let mut mgr = SessionManager::new();
    let sessions: Vec<SessionId> = (0..6).map(|_| mgr.create(0)).collect();
    for round in 0..2u32 {
        let mut pending = Vec::new();
        for (si, &sid) in sessions.iter().enumerate() {
            let base = (si as u32 + 1) * 10_000 + round * 100;
            let delta: Vec<u32> = if round == 0 {
                (base..base + 256).collect()
            } else {
                (base..base + 32).collect()
            };
            let (_t, rid) = mgr
                .begin_turn(&mut c, sid, ModelTarget::Base, delta, 16, true)
                .unwrap();
            pending.push((sid, rid));
        }
        drain_round(&mut c, &mut mgr, &pending);
    }
    let victim = (mgr.get(sessions[0]).unwrap().last_request.unwrap().0 % 2) as usize;
    let leased_before = c.replica(victim).leased_blocks();
    assert!(leased_before > 0, "warm sessions hold leases");

    // Round 2 in flight everywhere, then the victim goes silent.
    let mut pending = Vec::new();
    for (si, &sid) in sessions.iter().enumerate() {
        let base = (si as u32 + 1) * 10_000 + 200;
        let (_t, rid) = mgr
            .begin_turn(&mut c, sid, ModelTarget::Base, (base..base + 32).collect(), 16, true)
            .unwrap();
        pending.push((sid, rid));
    }
    for _ in 0..2 {
        c.step();
    }
    c.silence_replica(victim).unwrap();
    // 4 missed beats: past the suspect threshold (3), short of down (6).
    for _ in 0..4 {
        c.step();
    }
    assert_eq!(c.health(victim), ReplicaHealth::Up, "suspicion is not evacuation");
    assert_eq!(c.health_detail(victim), "suspected(4)");
    assert_eq!(c.router().stats.heartbeat_misses, 4);
    assert_eq!(c.router().stats.suspected_transitions, 1);
    assert!(c.take_failover_reports().is_empty(), "no failover below the threshold");

    // The partition heals: restore lifts the silence, the next beat
    // clears the suspicion.
    c.restore_replica(victim).unwrap();
    c.step();
    assert_eq!(c.health_detail(victim), "up");
    assert!(!c.is_suspected(victim));

    // Every round-2 turn finishes under its original id, nothing was
    // requeued, and the victim kept its leases.
    let outs = drain_round(&mut c, &mut mgr, &pending);
    assert_eq!(outs.len(), pending.len(), "zero lost requests");
    assert_eq!(c.router().stats.detected_failures, 0);
    assert_eq!(c.router().stats.replica_failures, 0);
    assert_eq!(c.router().stats.requeued_requests, 0);
    assert!(c.replica(victim).leased_blocks() >= leased_before, "leases survived");

    // Round 3: still sticky, and the victim's sessions are still warm.
    let sticky_before = c.router().stats.sticky_routed;
    let mut pending = Vec::new();
    for (si, &sid) in sessions.iter().enumerate() {
        let base = (si as u32 + 1) * 10_000 + 300;
        let (_t, rid) = mgr
            .begin_turn(&mut c, sid, ModelTarget::Base, (base..base + 32).collect(), 16, true)
            .unwrap();
        pending.push((sid, rid));
    }
    drain_round(&mut c, &mut mgr, &pending);
    assert_eq!(c.router().stats.sticky_routed - sticky_before, 6);
    for &sid in &sessions {
        let rec = mgr.get(sid).unwrap().turns().last().unwrap().clone();
        assert!(rec.cached_tokens > 256, "turn stayed warm: {}", rec.cached_tokens);
    }
    for sid in sessions {
        mgr.delete(&mut c, sid).unwrap();
    }
    c.replica(0).check_invariants().unwrap();
    c.replica(1).check_invariants().unwrap();
}

#[test]
fn silenced_then_declared_failed_runs_failover_exactly_once() {
    let mut c = cluster(2, RoutePolicy::PrefixAffinity);
    let p = SamplingParams { max_new_tokens: 32, ..Default::default() };
    let mut ids = Vec::new();
    for i in 0..8u32 {
        let base = (i + 1) * 1000;
        ids.push(c.submit(ModelTarget::Base, (base..base + 64).collect(), p).unwrap());
    }
    for _ in 0..2 {
        c.step();
    }
    c.silence_replica(1).unwrap();
    // Detection latency is exactly the down threshold: 6 silent steps.
    let mut reports = Vec::new();
    for _ in 0..6 {
        c.step();
        reports.append(&mut c.take_failover_reports());
    }
    assert_eq!(reports.len(), 1, "detection fired exactly once");
    assert_eq!(reports[0].replica, 1);
    assert!(reports[0].rejected.is_empty(), "survivor accepted the requeue");
    assert_eq!(c.health(1), ReplicaHealth::Down);
    assert_eq!(c.router().stats.detected_failures, 1);
    assert_eq!(c.router().stats.replica_failures, 1);

    // An operator declaring the same death afterwards is a state
    // conflict, not a second evacuation.
    let err = c.fail_replica(1).unwrap_err().to_string();
    assert!(err.contains("already down"), "{err}");
    assert_eq!(c.router().stats.replica_failures, 1);

    // Zero lost requests: every submission finishes under its original
    // id on the survivor.
    let mut done = HashMap::new();
    while done.len() < ids.len() {
        for o in c.take_finished() {
            done.insert(o.id, o);
        }
        if done.len() == ids.len() {
            break;
        }
        assert!(c.step(), "stalled with requests outstanding");
    }
    for id in &ids {
        assert!(done.contains_key(id), "{id:?} lost in failover");
    }
    // Detection stays quiet on later steps (Down is terminal until
    // restore).
    for _ in 0..8 {
        c.step();
    }
    assert!(c.take_failover_reports().is_empty());
    assert_eq!(c.router().stats.detected_failures, 1);
    c.replica(0).check_invariants().unwrap();
}

#[test]
fn autoscale_down_waits_for_in_flight_session_turn() {
    // Scale-down with a session turn in flight on the victim: the drain
    // finishes the turn in place, then retirement ships the session's
    // lease to the survivor — the next turn re-sticks there, warm.
    let engine = || {
        let mut cfg = presets::granite_8b();
        cfg.cache.prefix_migration = true;
        let reg = workload::build_registry(N_ADAPTERS, cfg.model.vocab_size, true);
        let exec = SimExecutor::new(&cfg);
        Engine::with_registry(cfg, reg, exec)
    };
    // Autoscaling stays off for the warm-up rounds (an idle fleet would
    // descale before the sessions even exist), then flips on with tight
    // thresholds just before the long turn.
    let mut c = Cluster::with_fleet(
        vec![engine(), engine()],
        alora_serve::cluster::RouterConfig::default(),
        alora_serve::config::FleetConfig::default(),
        2,
    )
    .unwrap();
    let mut mgr = SessionManager::new();
    // Two sessions submitted together: least-loaded spreads one first
    // turn onto each replica.
    let sa = mgr.create(0);
    let sb = mgr.create(0);
    let mut pending = Vec::new();
    for (i, &sid) in [sa, sb].iter().enumerate() {
        let base = (i as u32 + 1) * 50_000;
        let (_t, rid) = mgr
            .begin_turn(&mut c, sid, ModelTarget::Base, (base..base + 1024).collect(), 16, true)
            .unwrap();
        pending.push((sid, rid));
    }
    drain_round(&mut c, &mut mgr, &pending);
    let on_replica = |mgr: &SessionManager, sid: SessionId| {
        (mgr.get(sid).unwrap().last_request.unwrap().0 % 2) as usize
    };
    let victim_session = if on_replica(&mgr, sa) == 1 { sa } else { sb };
    assert_eq!(on_replica(&mgr, victim_session), 1, "one session per replica");
    assert!(c.replica(1).leased_blocks() > 0);

    // A long turn holds replica 1 busy while the otherwise-idle fleet
    // decides to descale.
    let (_t, rid) = mgr
        .begin_turn(&mut c, victim_session, ModelTarget::Base, (90_000..90_064).collect(), 64, true)
        .unwrap();
    c.set_fleet_config(alora_serve::config::FleetConfig {
        autoscale: true,
        min_replicas: 1,
        scale_down_after_steps: 2,
        queue_low: 10.0,
        queue_high: 20.0,
        cooldown_steps: 2,
        ..Default::default()
    })
    .unwrap();
    let mut saw_draining_with_work = false;
    let mut outs = HashMap::new();
    for _ in 0..400 {
        if c.health(1) == ReplicaHealth::Standby {
            break;
        }
        if c.health(1) == ReplicaHealth::Draining && c.replica(1).has_work() {
            saw_draining_with_work = true;
            assert_eq!(
                c.cluster_stats().unwrap().fleet.descaling,
                Some(1),
                "drain-in-progress surfaces in fleet stats"
            );
        }
        c.step();
        for o in c.take_finished() {
            outs.insert(o.id, o);
        }
    }
    assert!(saw_draining_with_work, "descale overlapped the in-flight turn");
    assert_eq!(c.health(1), ReplicaHealth::Standby, "victim retired after drain");
    let out = outs.get(&rid).expect("in-flight turn completed, not requeued");
    mgr.complete_turn(&mut c, victim_session, out).unwrap();
    assert_eq!(c.replica(1).metrics.requests_finished, 2, "turn finished in place");
    assert_eq!(c.router().stats.requeued_requests, 0, "drain is not failover");
    assert_eq!(c.router().stats.scale_downs, 1);

    // Retirement batch-migrated the session's lease to the survivor.
    assert_eq!(c.replica(1).leased_blocks(), 0, "victim holds no pins in standby");
    assert!(c.router().stats.migrations > 0, "lease shipped, not dropped");
    // Next turn re-sticks on the survivor and is warm off the migrated
    // prefix.
    let rec = mgr
        .run_turn(&mut c, victim_session, ModelTarget::Base, (91_000..91_032).collect(), 8, true)
        .unwrap();
    assert_eq!(on_replica(&mgr, victim_session), 0);
    assert!(rec.cached_tokens >= 1024, "re-stuck warm: {}", rec.cached_tokens);
    for sid in [sa, sb] {
        mgr.delete(&mut c, sid).unwrap();
    }
    c.replica(0).check_invariants().unwrap();
    c.replica(1).check_invariants().unwrap();
}

#[test]
fn single_engine_tests_equivalence_through_cluster_of_one() {
    // A 1-replica cluster must reproduce the plain engine's behaviour on
    // the same pipeline (same makespan, same hit rate) — the refactored
    // interface adds nothing but routing.
    let spec = PipelineSpec::base_adapter(512, 64, 16);
    let mut c = cluster(1, RoutePolicy::PrefixAffinity);
    let rc = pipeline::run_poisson(&mut c, &spec, 10, 4.0, 5);
    let mut e = sim_engine();
    let re = pipeline::run_poisson(&mut e, &spec, 10, 4.0, 5);
    assert_eq!(rc.makespan, re.makespan);
    assert_eq!(rc.eval_hit_rate(), re.eval_hit_rate());
    assert_eq!(rc.outputs.len(), re.outputs.len());
}
