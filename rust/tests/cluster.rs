//! Integration: replica cluster with base-aligned cache-affinity routing.
//!
//! The acceptance bar: on one multi-turn multi-adapter request stream over
//! ≥2 replicas, `PrefixAffinity` routing must achieve a strictly higher
//! aggregate prefix hit-rate than `RoundRobin` — i.e. the paper's
//! cross-model KV reuse survives horizontal scale-out only with
//! cache-affinity placement.

use alora_serve::adapter::AdapterId;
use alora_serve::cluster::{Cluster, RoutePolicy};
use alora_serve::config::presets;
use alora_serve::engine::{Engine, EngineDriver};
use alora_serve::pipeline::{self, workload, PipelineKind, PipelineSpec};
use alora_serve::simulator::SimExecutor;

const N_ADAPTERS: u32 = 3;

fn sim_engine() -> Engine<SimExecutor> {
    let cfg = presets::granite_8b();
    let reg = workload::build_registry(N_ADAPTERS, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

fn cluster(n: usize, policy: RoutePolicy) -> Cluster<SimExecutor> {
    Cluster::from_factory(n, policy, |_| sim_engine()).unwrap()
}

/// Multi-turn multi-adapter conversation: base draft → 3 adapter evals →
/// consolidated base call. Every non-root stage extends the draft's token
/// stream, so its prefix hits iff it lands on the draft's replica.
fn multi_turn_spec() -> PipelineSpec {
    PipelineSpec {
        kind: PipelineKind::MultiAdapter,
        prompt_len: 1024,
        base_gen: 64,
        eval_gen: 16,
        adapters: (0..N_ADAPTERS).map(AdapterId).collect(),
        base2_gen: 16,
        priority_continuations: false,
    }
}

fn run_policy(policy: RoutePolicy, replicas: usize) -> (f64, Cluster<SimExecutor>) {
    let mut c = cluster(replicas, policy);
    // Same seed → bit-identical prompt stream and arrival times across
    // policies; only placement differs.
    let r = pipeline::run_poisson(&mut c, &multi_turn_spec(), 24, 8.0, 42);
    assert_eq!(r.outputs.len(), 24 * 5, "all stages completed");
    let hit = c.aggregate_hit_rate();
    (hit, c)
}

#[test]
fn prefix_affinity_beats_round_robin_on_same_stream() {
    let (hit_affinity, ca) = run_policy(RoutePolicy::PrefixAffinity, 2);
    let (hit_rr, _) = run_policy(RoutePolicy::RoundRobin, 2);
    assert!(
        hit_affinity > hit_rr,
        "affinity hit-rate {hit_affinity:.3} must strictly beat round-robin {hit_rr:.3}"
    );
    // And not vacuously: the warm stream really reuses prefixes.
    assert!(hit_affinity > 0.3, "affinity hit-rate collapsed: {hit_affinity:.3}");
    // 4 follow-up stages per conversation had a warm replica to find.
    let stats = &ca.router().stats;
    assert!(stats.affinity_hits > 0, "no warm placements recorded");
    assert_eq!(
        stats.total_routed(),
        24 * 5,
        "every stage went through the router"
    );
}

#[test]
fn affinity_gap_widens_with_more_replicas() {
    // Round-robin spreads a conversation's follow-ups over N replicas, so
    // its hit-rate decays with N while affinity's holds roughly flat.
    let (aff2, _) = run_policy(RoutePolicy::PrefixAffinity, 2);
    let (aff4, _) = run_policy(RoutePolicy::PrefixAffinity, 4);
    let (rr4, _) = run_policy(RoutePolicy::RoundRobin, 4);
    assert!(aff4 > rr4, "affinity {aff4:.3} vs rr {rr4:.3} at 4 replicas");
    assert!(
        aff4 > 0.5 * aff2,
        "affinity should not collapse with scale: {aff2:.3} -> {aff4:.3}"
    );
}

#[test]
fn coordinator_children_inherit_parent_replica() {
    // Drive conversations over a 3-replica cluster and check placement by
    // its observable consequence: every follow-up stage hits at least its
    // conversation's full 1024-token prompt from cache. Prompts are unique
    // per conversation, so that is only possible on the replica that
    // served the draft — the child inherited its parent's placement.
    let mut c = cluster(3, RoutePolicy::PrefixAffinity);
    let r = pipeline::run_poisson(&mut c, &multi_turn_spec(), 9, 6.0, 7);
    let follow_ups: Vec<_> = r
        .outputs
        .iter()
        .filter(|(s, _)| !matches!(s, pipeline::Stage::Base1))
        .collect();
    assert_eq!(follow_ups.len(), 9 * 4);
    for (stage, out) in &follow_ups {
        assert!(
            out.num_cached_tokens >= 1024,
            "{stage:?} ({:?}) re-prefilled on a cold replica: {} cached",
            out.id,
            out.num_cached_tokens
        );
    }
    assert!(!c.has_work());
}

#[test]
fn cluster_deterministic_across_runs() {
    let run = || {
        let mut c = cluster(2, RoutePolicy::PrefixAffinity);
        let r = pipeline::run_poisson(&mut c, &multi_turn_spec(), 8, 4.0, 21);
        (r.makespan, c.aggregate_hit_rate(), c.router().stats.routed.clone())
    };
    assert_eq!(run(), run());
}

#[test]
fn single_engine_tests_equivalence_through_cluster_of_one() {
    // A 1-replica cluster must reproduce the plain engine's behaviour on
    // the same pipeline (same makespan, same hit rate) — the refactored
    // interface adds nothing but routing.
    let spec = PipelineSpec::base_adapter(512, 64, 16);
    let mut c = cluster(1, RoutePolicy::PrefixAffinity);
    let rc = pipeline::run_poisson(&mut c, &spec, 10, 4.0, 5);
    let mut e = sim_engine();
    let re = pipeline::run_poisson(&mut e, &spec, 10, 4.0, 5);
    assert_eq!(rc.makespan, re.makespan);
    assert_eq!(rc.eval_hit_rate(), re.eval_hit_rate());
    assert_eq!(rc.outputs.len(), re.outputs.len());
}
