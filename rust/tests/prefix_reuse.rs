//! Integration: cross-model prefix-cache reuse through the full engine
//! (scheduler + block manager + hashing + masks) on the simulator.
//!
//! These are the engine-level twins of python/tests/test_alora_reuse.py's
//! numeric proofs: here we assert the *cache behaviour* (who hits whose
//! blocks) matches the paper's Figure 3/4 semantics in every direction.

use alora_serve::adapter::AdapterId;
use alora_serve::config::presets;
use alora_serve::engine::Engine;
use alora_serve::pipeline::workload;
use alora_serve::request::{ModelTarget, RequestOutput, SamplingParams};
use alora_serve::simulator::SimExecutor;
use alora_serve::util::rng::Rng;

fn engine(alora: bool) -> Engine<SimExecutor> {
    let mut cfg = presets::granite_8b();
    cfg.cache.base_aligned_hashing = alora;
    let reg = workload::build_registry(3, cfg.model.vocab_size, alora);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

fn run(
    e: &mut Engine<SimExecutor>,
    target: ModelTarget,
    prompt: Vec<u32>,
    gen: u32,
) -> RequestOutput {
    let id = e
        .submit(target, prompt, SamplingParams { max_new_tokens: gen, ..Default::default() })
        .unwrap();
    e.run_to_completion(id)
}

#[test]
fn base_to_alora_and_back_full_cycle() {
    let mut e = engine(true);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(1);
    let prompt = workload::prompt(&mut rng, 2048, vocab);

    // turn 1: base
    let b1 = run(&mut e, ModelTarget::Base, prompt.clone(), 128);
    assert_eq!(b1.num_cached_tokens, 0);

    // turn 2: aLoRA eval hits the base blocks
    let mut ev = prompt.clone();
    ev.extend(b1.output_tokens.iter());
    ev.extend(workload::invocation_for(vocab, 0));
    let al = run(&mut e, ModelTarget::Adapter(AdapterId(0)), ev, 16);
    assert!(
        al.num_cached_tokens >= 2048,
        "aLoRA must reuse base blocks, got {}",
        al.num_cached_tokens
    );

    // turn 3: base resumes, hitting its own conversation blocks (the
    // adapter's post-activation blocks are separate and untouched).
    let mut cont = prompt.clone();
    cont.extend(b1.output_tokens.iter());
    cont.push(1);
    let b2 = run(&mut e, ModelTarget::Base, cont, 64);
    assert!(b2.num_cached_tokens >= 2048);

    e.check_invariants().unwrap();
}

#[test]
fn alora_to_alora_cross_adapter_reuse() {
    let mut e = engine(true);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(2);
    let prompt = workload::prompt(&mut rng, 1024, vocab);

    // adapter 0 evaluates first (prefills pre-activation blocks)
    let mut ev0 = prompt.clone();
    ev0.extend(workload::invocation_for(vocab, 0));
    let a0 = run(&mut e, ModelTarget::Adapter(AdapterId(0)), ev0, 16);
    assert_eq!(a0.num_cached_tokens, 0, "cold cache");

    // adapter 1 over the same context reuses adapter 0's pre-activation
    // blocks (they hash as base).
    let mut ev1 = prompt.clone();
    ev1.extend(workload::invocation_for(vocab, 1));
    let a1 = run(&mut e, ModelTarget::Adapter(AdapterId(1)), ev1, 16);
    assert!(
        a1.num_cached_tokens >= 1024 - 16,
        "aLoRA→aLoRA reuse failed: {}",
        a1.num_cached_tokens
    );
}

#[test]
fn vanilla_vllm_mode_isolates_all_adapters() {
    let mut e = engine(false);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(3);
    let prompt = workload::prompt(&mut rng, 1024, vocab);

    let b = run(&mut e, ModelTarget::Base, prompt.clone(), 64);
    let mut ev = prompt.clone();
    ev.extend(b.output_tokens.iter());
    ev.extend(workload::invocation_for(vocab, 0));
    let l = run(&mut e, ModelTarget::Adapter(AdapterId(0)), ev.clone(), 16);
    assert_eq!(l.num_cached_tokens, 0, "baseline must re-prefill");

    // but the SAME adapter re-invoked hits its own cache
    let l2 = run(&mut e, ModelTarget::Adapter(AdapterId(0)), ev, 16);
    assert!(l2.num_cached_tokens > 0, "same-adapter reuse still works");
}

#[test]
fn base_reuses_only_pre_activation_blocks() {
    let mut e = engine(true);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(4);
    let prompt = workload::prompt(&mut rng, 512, vocab);

    // aLoRA runs a long evaluation (generates 128 post-activation tokens)
    let mut ev = prompt.clone();
    ev.extend(workload::invocation_for(vocab, 2));
    let a = run(&mut e, ModelTarget::Adapter(AdapterId(2)), ev.clone(), 128);

    // base over prompt+eval-output: hits exactly the pre-activation span
    // (512 tokens rounded to blocks), not the adapter's generated blocks.
    let mut cont = prompt.clone();
    cont.extend(a.output_tokens.iter());
    let b = run(&mut e, ModelTarget::Base, cont, 16);
    assert_eq!(b.num_cached_tokens, 512, "only pre-activation blocks reusable");
}

#[test]
fn eviction_then_recompute_consistency() {
    // Tiny cache: first conversation's blocks get evicted by a second;
    // re-running the first re-prefills without error and block accounting
    // stays exact.
    let mut cfg = presets::granite_8b();
    cfg.cache.max_kv_tokens = 8192;
    cfg.scheduler.max_seq_len = 8192;
    cfg.cache.base_aligned_hashing = true;
    let reg = workload::build_registry(1, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    let mut e = Engine::with_registry(cfg, reg, exec);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(5);

    let p1 = workload::prompt(&mut rng, 3000, vocab);
    let p2 = workload::prompt(&mut rng, 4000, vocab);
    let _ = run(&mut e, ModelTarget::Base, p1.clone(), 32);
    let _ = run(&mut e, ModelTarget::Base, p2, 32); // evicts much of p1
    let again = run(&mut e, ModelTarget::Base, p1, 32);
    // partial (possibly zero) reuse — must complete correctly either way
    assert_eq!(again.output_tokens.len(), 32);
    e.check_invariants().unwrap();
}

#[test]
fn preemption_storm_conserves_blocks_and_finishes() {
    let mut cfg = presets::granite_8b();
    cfg.cache.max_kv_tokens = 4096; // very tight
    cfg.scheduler.max_seq_len = 2048;
    let reg = workload::build_registry(1, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    let mut e = Engine::with_registry(cfg, reg, exec);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(6);

    let mut ids = Vec::new();
    for _ in 0..8 {
        let p = workload::prompt(&mut rng, 1024, vocab);
        ids.push(
            e.submit(
                ModelTarget::Base,
                p,
                SamplingParams { max_new_tokens: 512, ..Default::default() },
            )
            .unwrap(),
        );
    }
    e.run_until_idle();
    assert_eq!(e.metrics.requests_finished, 8);
    assert!(e.metrics.requests_preempted > 0, "tight cache must preempt");
    e.check_invariants().unwrap();
}

#[test]
fn hit_rates_reported_in_metrics_pipeline() {
    let mut e = engine(true);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(7);
    let prompt = workload::prompt(&mut rng, 2048, vocab);
    let b = run(&mut e, ModelTarget::Base, prompt.clone(), 32);
    let mut ev = prompt;
    ev.extend(b.output_tokens.iter());
    ev.extend(workload::invocation_for(vocab, 0));
    let _ = run(&mut e, ModelTarget::Adapter(AdapterId(0)), ev, 16);

    assert!(e.metrics.cache_hit_rate() > 0.3);
    let prom = e.metrics.render_prometheus();
    assert!(prom.contains("prefix_cache_hit_tokens_total"));
    let stats = e.kv_stats();
    assert!(stats.pool.hits > 0);
    assert!(stats.hit_rate() > 0.0);
}
