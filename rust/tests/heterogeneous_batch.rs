//! Integration: heterogeneous batching — base, standard-LoRA and multiple
//! aLoRAs with different invocation points scheduled in ONE engine step,
//! with a single flat activation mask (paper Appendix B; cross-adapter
//! batching is the paper's §5 future work, which this scheduler supports
//! natively because the mask and the hash context are per-request).

use alora_serve::adapter::{AdapterId, AdapterKind, AdapterRegistry};
use alora_serve::config::presets;
use alora_serve::engine::{build_batch_mask, Engine, Executor, StepResult};
use alora_serve::kvcache::manager::KvCacheManager;
use alora_serve::pipeline::workload;
use alora_serve::request::{ModelTarget, Request, RequestId, SamplingParams};
use alora_serve::scheduler::ScheduledStep;
use alora_serve::util::fxmap::FxHashMap;

/// Executor that records the batch composition of every step.
#[derive(Default)]
struct RecordingExecutor {
    batches: Vec<Vec<(RequestId, bool)>>, // (id, is_decode)
    mask_snapshots: Vec<Vec<bool>>,
}

impl Executor for RecordingExecutor {
    fn execute(
        &mut self,
        step: &ScheduledStep,
        _reqs: &FxHashMap<RequestId, Request>,
        _kv: &KvCacheManager,
        mask: &alora_serve::engine::BatchMask,
    ) -> StepResult {
        self.batches
            .push(step.seqs.iter().map(|s| (s.id, s.is_decode)).collect());
        self.mask_snapshots.push(mask.mask_pre.clone());
        StepResult {
            elapsed: 0.001,
            sampled: step
                .seqs
                .iter()
                .filter(|s| s.produces_token)
                .map(|s| (s.id, 1))
                .collect(),
        }
    }
}

fn mixed_registry(vocab: u32) -> AdapterRegistry {
    let mut reg = AdapterRegistry::new();
    // adapters 0,1: aLoRA with distinct invocation sequences
    reg.register(
        "alora-0",
        AdapterKind::ALora { invocation_tokens: workload::invocation_for(vocab, 0) },
        32,
    );
    reg.register(
        "alora-1",
        AdapterKind::ALora { invocation_tokens: workload::invocation_for(vocab, 1) },
        32,
    );
    // adapter 2: standard LoRA
    reg.register("lora-2", AdapterKind::Lora, 8);
    reg
}

#[test]
fn one_step_carries_base_lora_and_aloras() {
    let cfg = presets::granite_8b();
    let vocab = cfg.model.vocab_size;
    let reg = mixed_registry(vocab);
    let mut e = Engine::with_registry(cfg, reg, RecordingExecutor::default());

    let mut rng = alora_serve::util::rng::Rng::new(1);
    let shared: Vec<u32> = workload::prompt(&mut rng, 64, vocab);

    // Four requests with different targets & invocation points, submitted
    // together so the first schedule() packs them into one batch.
    let mut p0 = shared.clone();
    p0.extend(workload::invocation_for(vocab, 0)); // aLoRA-0, activates @64
    let mut p1 = shared.clone();
    p1.extend(workload::invocation_for(vocab, 1));
    p1.extend([7, 8, 9]); // aLoRA-1, activates @64, longer tail
    let params = SamplingParams { max_new_tokens: 4, ..Default::default() };

    let ids = [
        e.submit(ModelTarget::Base, shared.clone(), params).unwrap(),
        e.submit(ModelTarget::Adapter(AdapterId(0)), p0, params).unwrap(),
        e.submit(ModelTarget::Adapter(AdapterId(1)), p1, params).unwrap(),
        e.submit(ModelTarget::Adapter(AdapterId(2)), shared.clone(), params).unwrap(),
    ];
    e.step();
    {
        let exec = e.executor();
        let first = &exec.batches[0];
        assert_eq!(first.len(), 4, "all four admitted into one step: {first:?}");
        // Mask: base span all-pre; LoRA span all-post; aLoRA spans split.
        let mask = &exec.mask_snapshots[0];
        assert!(mask.iter().take(64).all(|&b| b), "base tokens pre");
        assert!(mask.len() > 64 * 4 - 1);
    }
    e.run_until_idle();
    let outs = e.take_finished();
    assert_eq!(outs.len(), 4);
    // aLoRA requests share the cold prefill? No — all arrived together, so
    // no cross hits this round; but re-submitting aLoRA-1 now hits the
    // shared prefix committed by ANY of the base/aLoRA requests.
    let mut p1b = shared.clone();
    p1b.extend(workload::invocation_for(vocab, 1));
    let id = e
        .submit(ModelTarget::Adapter(AdapterId(1)), p1b, params)
        .unwrap();
    let out = e.run_to_completion(id);
    assert_eq!(out.num_cached_tokens, 64, "warm cross-model hit");
    let _ = ids;
}

#[test]
fn mask_spans_match_invocation_points_in_mixed_batch() {
    // Direct mask-builder check with mixed targets mid-sequence.
    let cfg = presets::granite_8b();
    let vocab = cfg.model.vocab_size;
    let reg = mixed_registry(vocab);
    let mut e = Engine::with_registry(cfg, reg, RecordingExecutor::default());
    let params = SamplingParams { max_new_tokens: 2, ..Default::default() };

    let mut rng = alora_serve::util::rng::Rng::new(2);
    let prompt: Vec<u32> = workload::prompt(&mut rng, 32, vocab);
    let mut with_inv = prompt.clone();
    with_inv.extend(workload::invocation_for(vocab, 0));

    let a = e.submit(ModelTarget::Adapter(AdapterId(0)), with_inv, params).unwrap();
    let l = e.submit(ModelTarget::Adapter(AdapterId(2)), prompt, params).unwrap();
    e.step();
    let exec = e.executor();
    let mask = &exec.mask_snapshots[0];
    // reconstruct spans: first seq = aLoRA (36 tokens), second = LoRA (32)
    let (alora_span, lora_span) = mask.split_at(36);
    assert!(alora_span[..32].iter().all(|&b| b), "pre-activation");
    assert!(alora_span[32..].iter().all(|&b| !b), "invocation tokens adapted");
    assert!(lora_span.iter().all(|&b| !b), "LoRA adapts everything");
    let _ = (a, l);
    e.run_until_idle();
}

#[test]
fn decode_steps_stay_heterogeneous() {
    // After prefill, all four requests decode in the same step with
    // per-token masks that reflect their (different) activation points.
    let cfg = presets::granite_8b();
    let vocab = cfg.model.vocab_size;
    let reg = mixed_registry(vocab);
    let mut e = Engine::with_registry(cfg, reg, RecordingExecutor::default());
    let params = SamplingParams { max_new_tokens: 8, ..Default::default() };
    let mut rng = alora_serve::util::rng::Rng::new(3);
    let prompt: Vec<u32> = workload::prompt(&mut rng, 16, vocab);
    let mut with_inv = prompt.clone();
    with_inv.extend(workload::invocation_for(vocab, 1));

    e.submit(ModelTarget::Base, prompt.clone(), params).unwrap();
    e.submit(ModelTarget::Adapter(AdapterId(1)), with_inv, params).unwrap();
    e.submit(ModelTarget::Adapter(AdapterId(2)), prompt, params).unwrap();
    e.run_until_idle();

    let exec = e.executor();
    // find a step where all three decode together
    let mixed_decode = exec
        .batches
        .iter()
        .zip(&exec.mask_snapshots)
        .find(|(b, _)| b.len() == 3 && b.iter().all(|(_, d)| *d));
    let (batch, mask) = mixed_decode.expect("expected a 3-way decode step");
    assert_eq!(mask.len(), 3, "one mask slot per decode token");
    // base decode token is pre (never activates); adapter decodes are post
    assert_eq!(batch.len(), 3);
    assert!(mask.iter().filter(|&&b| !b).count() >= 2, "{mask:?}");
}
