//! Integration: the REAL PJRT path against the goldens exported by aot.py.
//!
//! Validates the full L3→L2→L1 composition numerically: the rust-loaded
//! artifact reproduces the python model's logits, cross-model KV reuse is
//! exact at both the raw-model and engine level, and the engine's block
//! store physically carries base blocks into aLoRA requests.
//!
//! All tests skip (cleanly) when `artifacts/` has not been built.

use std::path::PathBuf;

use alora_serve::adapter::{AdapterId, AdapterRegistry};
use alora_serve::config::presets;
use alora_serve::engine::Engine;
use alora_serve::request::{ModelTarget, SamplingParams};
use alora_serve::runtime::{KvBuf, RealExecutor, TinyModel};
use alora_serve::util::json::Json;

fn artifacts() -> Option<PathBuf> {
    let dir = TinyModel::default_dir();
    if TinyModel::artifacts_present(&dir) {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn load_golden(dir: &std::path::Path) -> Json {
    Json::parse_file(&dir.join("golden.json")).expect("golden.json")
}

fn allclose(a: &[f32], b: &[f64], atol: f64) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| ((*x as f64) - y).abs() <= atol)
}

struct Ctx {
    model: TinyModel,
    golden: Json,
}

fn ctx() -> Option<Ctx> {
    let dir = artifacts()?;
    Some(Ctx { model: TinyModel::load(&dir).expect("load model"), golden: load_golden(&dir) })
}

fn mask_for(m: &alora_serve::runtime::Manifest, inv_start: usize) -> Vec<bool> {
    (0..m.max_seq_len).map(|p| p < inv_start).collect()
}

fn onehot(m: &alora_serve::runtime::Manifest, id: Option<usize>) -> Vec<f32> {
    let mut v = vec![0.0; m.n_adapters];
    if let Some(i) = id {
        v[i] = 1.0;
    }
    v
}

#[test]
fn base_prefill_matches_golden_logits() {
    let Some(c) = ctx() else { return };
    let m = c.model.manifest.clone();
    let prompt = c.golden.req("prompt").u32_vec().unwrap();
    let plen = prompt.len();
    let kv = KvBuf::zeros(&m);
    let (logits, _) = c
        .model
        .step(&prompt, &kv, 0, plen, &mask_for(&m, m.max_seq_len), &onehot(&m, None))
        .unwrap();
    let head = c.golden.req("base_logits_head").f64_vec().unwrap();
    let atol = c.golden.req("atol").as_f64().unwrap();
    assert!(
        allclose(&logits[..head.len()], &head, atol),
        "base logits diverge from python golden"
    );
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as u64;
    assert_eq!(argmax, c.golden.req("base_next_token").as_u64().unwrap());
}

#[test]
fn cross_model_reuse_exact_at_model_level() {
    let Some(c) = ctx() else { return };
    let m = c.model.manifest.clone();
    let g = &c.golden;
    let prompt = g.req("prompt").u32_vec().unwrap();
    let plen = prompt.len();
    let eval_tokens = g.req("eval_tokens").u32_vec().unwrap();
    let inv_start = g.req("inv_start").as_u64().unwrap() as usize;
    let adapter = g.req("adapter_id").as_u64().unwrap() as usize;
    let atol = g.req("atol").as_f64().unwrap();

    // base prefill
    let kv0 = KvBuf::zeros(&m);
    let (_, kv_base) = c
        .model
        .step(&prompt, &kv0, 0, plen, &mask_for(&m, m.max_seq_len), &onehot(&m, None))
        .unwrap();

    // (a) full recompute with the adapter
    let (full, _) = c
        .model
        .step(
            &eval_tokens,
            &kv0,
            0,
            eval_tokens.len(),
            &mask_for(&m, inv_start),
            &onehot(&m, Some(adapter)),
        )
        .unwrap();
    // (b) REUSE the base KV, computing only [plen, len)
    let (reuse, _) = c
        .model
        .step(
            &eval_tokens,
            &kv_base,
            plen,
            eval_tokens.len(),
            &mask_for(&m, inv_start),
            &onehot(&m, Some(adapter)),
        )
        .unwrap();

    let max_diff = full
        .iter()
        .zip(&reuse)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-4, "cross-model reuse not exact: {max_diff}");

    // against golden heads too
    let head = g.req("alora_reuse_logits_head").f64_vec().unwrap();
    assert!(allclose(&reuse[..head.len()], &head, atol));

    // and the LoRA (mask-0) logits must differ
    let (lora, _) = c
        .model
        .step(
            &eval_tokens,
            &kv0,
            0,
            eval_tokens.len(),
            &mask_for(&m, 0),
            &onehot(&m, Some(adapter)),
        )
        .unwrap();
    let lora_diff = full
        .iter()
        .zip(&lora)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(lora_diff > 1e-3, "LoRA and aLoRA must differ");
}

#[test]
fn decode_chain_matches_golden() {
    let Some(c) = ctx() else { return };
    let m = c.model.manifest.clone();
    let g = &c.golden;
    let prompt = g.req("prompt").u32_vec().unwrap();
    let y = g.req("base_next_token").as_u64().unwrap() as u32;
    let expected = g.req("base_decode_tokens").u32_vec().unwrap();

    let kv0 = KvBuf::zeros(&m);
    let (_, mut kv) = c
        .model
        .step(&prompt, &kv0, 0, prompt.len(), &mask_for(&m, m.max_seq_len), &onehot(&m, None))
        .unwrap();
    let mut toks = prompt.clone();
    toks.push(y);
    let mut got = Vec::new();
    for _ in 0..expected.len() {
        let (logits, kv2) = c
            .model
            .step(
                &toks,
                &kv,
                toks.len() - 1,
                toks.len(),
                &mask_for(&m, m.max_seq_len),
                &onehot(&m, None),
            )
            .unwrap();
        kv = kv2;
        let next = alora_serve::runtime::sampler::argmax(&logits);
        got.push(next);
        toks.push(next);
    }
    assert_eq!(got, expected, "greedy decode chain diverged from python");
}

#[test]
fn engine_level_real_reuse_and_correct_sampling() {
    let Some(dir) = artifacts() else { return };
    let exec = RealExecutor::load(&dir, 0).unwrap();
    let manifest = exec.manifest().clone();
    let golden = load_golden(&dir);

    let cfg = presets::tiny();
    let reg = AdapterRegistry::tiny_default(
        manifest.n_adapters as u32,
        manifest.vocab_size as u32,
        manifest.invocation_tokens[0].len() as u32,
    );
    let mut e = Engine::with_registry(cfg, reg, exec);

    let prompt = golden.req("prompt").u32_vec().unwrap();
    let base = e
        .submit(
            ModelTarget::Base,
            prompt.clone(),
            SamplingParams { max_new_tokens: 1, ..Default::default() },
        )
        .unwrap();
    let base_out = e.run_to_completion(base);
    assert_eq!(
        base_out.output_tokens[0] as u64,
        golden.req("base_next_token").as_u64().unwrap()
    );

    // aLoRA eval through the engine: hits base blocks AND matches the
    // golden argmax (i.e. reused physical blocks carry exact tensors).
    let ev = golden.req("eval_tokens").u32_vec().unwrap();
    let aid = golden.req("adapter_id").as_u64().unwrap() as u32;
    let al = e
        .submit(
            ModelTarget::Adapter(AdapterId(aid)),
            ev,
            SamplingParams { max_new_tokens: 1, ..Default::default() },
        )
        .unwrap();
    let al_out = e.run_to_completion(al);
    assert!(al_out.num_cached_tokens > 0, "no cross-model hit");
    assert_eq!(
        al_out.output_tokens[0] as u64,
        golden.req("alora_argmax").as_u64().unwrap(),
        "engine-level reuse produced wrong logits"
    );
    e.check_invariants().unwrap();
}

#[test]
fn engine_real_multiturn_decode_matches_incremental() {
    // The engine's chunked prefill + decode over the real model must agree
    // with the raw incremental path for the same token stream.
    let Some(dir) = artifacts() else { return };
    let exec = RealExecutor::load(&dir, 0).unwrap();
    let manifest = exec.manifest().clone();
    let cfg = presets::tiny();
    let reg = AdapterRegistry::tiny_default(
        manifest.n_adapters as u32,
        manifest.vocab_size as u32,
        manifest.invocation_tokens[0].len() as u32,
    );
    let mut e = Engine::with_registry(cfg, reg, exec);

    let prompt: Vec<u32> = (40..72).collect();
    let id = e
        .submit(
            ModelTarget::Base,
            prompt.clone(),
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        )
        .unwrap();
    let out = e.run_to_completion(id);

    // raw reference
    let model = TinyModel::load(&dir).unwrap();
    let m = model.manifest.clone();
    let kv0 = KvBuf::zeros(&m);
    let mask: Vec<bool> = vec![true; m.max_seq_len];
    let oh = vec![0.0f32; m.n_adapters];
    let (mut logits, mut kv) = model.step(&prompt, &kv0, 0, prompt.len(), &mask, &oh).unwrap();
    let mut toks = prompt.clone();
    let mut expect = Vec::new();
    for _ in 0..4 {
        let next = alora_serve::runtime::sampler::argmax(&logits);
        expect.push(next);
        toks.push(next);
        let r = model
            .step(&toks, &kv, toks.len() - 1, toks.len(), &mask, &oh)
            .unwrap();
        logits = r.0;
        kv = r.1;
    }
    assert_eq!(out.output_tokens, expect);
}
