//! Integration: the HTTP entrypoint under concurrent clients.

use std::io::{Read, Write};
use std::net::TcpStream;

use alora_serve::engine::Engine;
use alora_serve::pipeline::workload;
use alora_serve::server::Server;
use alora_serve::simulator::SimExecutor;

fn start() -> Server<Engine<SimExecutor>> {
    let cfg = alora_serve::config::presets::granite_8b();
    let reg = workload::build_registry(2, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    Server::start(Engine::with_registry(cfg, reg, exec), "127.0.0.1:0").unwrap()
}

fn post(addr: std::net::SocketAddr, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(
        format!(
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

#[test]
fn concurrent_clients_all_served() {
    let mut srv = start();
    let addr = srv.addr();
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": [{}], "max_new_tokens": 4}}"#,
                    (1..32).map(|t| (t + i).to_string()).collect::<Vec<_>>().join(",")
                );
                post(addr, &body)
            })
        })
        .collect();
    for h in handles {
        let resp = h.join().unwrap();
        assert!(resp.contains("200 OK"), "{resp}");
        assert!(resp.contains("\"tokens\""));
    }
    // metrics reflect the workload
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut m = String::new();
    s.read_to_string(&mut m).unwrap();
    assert!(m.contains("alora_serve_requests_finished_total 8"), "{m}");
    // GET /cluster on a single-engine server returns a one-replica stats
    // document (API-consistency satellite), not 404.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /cluster HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut c = String::new();
    s.read_to_string(&mut c).unwrap();
    assert!(c.contains("200 OK"), "{c}");
    let j = alora_serve::util::json::Json::parse(c.lines().last().unwrap()).unwrap();
    assert_eq!(
        j.get("policy").and_then(|p| p.as_str()),
        Some("single"),
        "{c}"
    );
    let reps = j.get("replicas").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(reps.len(), 1);
    assert_eq!(reps[0].get("finished").and_then(|f| f.as_u64()), Some(8));
    srv.shutdown();
}

#[test]
fn adapter_requests_share_cache_across_http_calls() {
    let mut srv = start();
    let addr = srv.addr();
    // long base request
    let prompt: Vec<String> = (100..612).map(|t| (t % 4000).to_string()).collect();
    let body = format!(r#"{{"prompt": [{}], "max_new_tokens": 8}}"#, prompt.join(","));
    let r1 = post(addr, &body);
    assert!(r1.contains("200 OK"));
    // adapter over the same prefix
    let inv = workload::invocation_for(49_155, 0);
    let mut p2: Vec<String> = (100..612).map(|t| (t % 4000).to_string()).collect();
    p2.extend(inv.iter().map(|t| t.to_string()));
    let body = format!(
        r#"{{"prompt": [{}], "adapter": "alora-0", "max_new_tokens": 4}}"#,
        p2.join(",")
    );
    let r2 = post(addr, &body);
    assert!(r2.contains("200 OK"), "{r2}");
    // hit rate > 0 reported in the response json
    let hit = r2
        .lines()
        .last()
        .and_then(|l| alora_serve::util::json::Json::parse(l).ok())
        .and_then(|j| j.get("cache_hit_rate").and_then(|v| v.as_f64()))
        .unwrap_or(0.0);
    assert!(hit > 0.5, "expected cross-model hit over HTTP, got {hit}");
    srv.shutdown();
}
