//! Integration: unified GPU-memory accounting — adapter-weight residency
//! paged against the KV block pool, end to end.
//!
//! Acceptance bars (ISSUE 3):
//! (a) under a budget that cannot hold all adapters, requests for
//!     non-resident adapters still complete via load+evict, with no
//!     running request's KV blocks reclaimed;
//! (b) a cluster with adapter-aware routing achieves a strictly higher
//!     aggregate adapter-residency hit-rate than RoundRobin on the same
//!     multi-adapter stream;
//! (c) with an unbounded budget, behavior and figure outputs are
//!     bit-identical to pre-refactor (always-resident) semantics.

use alora_serve::adapter::AdapterId;
use alora_serve::cluster::{Cluster, RoutePolicy};
use alora_serve::engine::{Engine, EngineDriver};
use alora_serve::figures::adapter_memory::{cfg_for, run_point};
use alora_serve::pipeline::workload;
use alora_serve::request::{ModelTarget, SamplingParams};
use alora_serve::simulator::SimExecutor;
use alora_serve::util::rng::Rng;

/// The figure's own paged config (granite-8b cost model on a shrunk
/// device, `budget_blocks` pages for KV + weights, each rank-32 aLoRA 32
/// pages) — shared so these acceptance tests exercise exactly the
/// configuration `figures/adapter_memory.rs` sweeps.
fn paged_engine(budget_blocks: u64, n_adapters: u32) -> Engine<SimExecutor> {
    let cfg = cfg_for(budget_blocks, true);
    let reg = workload::build_registry(n_adapters, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    Engine::with_registry(cfg, reg, exec)
}

#[test]
fn acceptance_a_load_evict_completes_without_reclaiming_running_kv() {
    // 160-block budget, 6 adapters × 32 weight blocks = 192 > budget: the
    // device can never hold all six. One request per adapter, submitted
    // together — admissions beyond what fits must stall, load on drain,
    // and evict idle adapters, while running requests keep their KV.
    let mut e = paged_engine(160, 6);
    let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
    let mut rng = Rng::new(17);
    let vocab = e.cfg.model.vocab_size;
    let mut ids = Vec::new();
    for a in 0..6u32 {
        let prompt = workload::prompt(&mut rng, 256, vocab);
        ids.push(
            e.submit(ModelTarget::Adapter(AdapterId(a)), prompt, p).unwrap(),
        );
    }
    e.run_until_idle();
    let outs = e.take_finished();
    assert_eq!(outs.len(), 6, "every request completed");
    for out in &outs {
        assert_eq!(out.output_tokens.len(), 8, "{:?} cut short", out.id);
        assert_eq!(out.preemptions, 0, "{:?} lost KV to a weight load", out.id);
    }
    // No running request's blocks were ever reclaimed — loads made room
    // exclusively by evicting idle adapters (and cold cache).
    assert_eq!(e.kv_stats().preemptions, 0);
    let rs = e.residency().stats();
    assert_eq!(rs.loads, 6, "each adapter loaded for its request");
    assert!(rs.evictions >= 2, "over-budget set must evict: {rs:?}");
    assert!(rs.load_stall_steps > 0, "admissions had to wait for memory");
    assert_eq!(rs.adapter_admissions, 6);
    e.check_invariants().unwrap();
    // Idle engine: only resident adapter weights may still hold pages.
    assert_eq!(
        e.num_free_blocks() as usize + e.residency().resident_blocks(),
        e.num_total_blocks() as usize
    );
}

#[test]
fn acceptance_b_adapter_aware_routing_beats_round_robin_hit_rate() {
    // 2 replicas × 160-block budget, 5 adapters: one replica can hold at
    // most ~4 adapters beside KV, so the fleet must PARTITION the adapter
    // set to stop thrashing. Same seeded stream for both policies: 4
    // rounds of one request per adapter with unique prompts (so prefix
    // affinity is irrelevant and only adapter placement differs).
    let run = |policy: RoutePolicy| {
        let mut c = Cluster::from_factory(2, policy, |_| paged_engine(160, 5)).unwrap();
        let p = SamplingParams { max_new_tokens: 8, ..Default::default() };
        let mut rng = Rng::new(23);
        let vocab = c.config().model.vocab_size;
        for _round in 0..4 {
            for a in 0..5u32 {
                let prompt = workload::prompt(&mut rng, 256, vocab);
                c.submit(ModelTarget::Adapter(AdapterId(a)), prompt, p).unwrap();
            }
            c.run_until_idle();
        }
        assert_eq!(c.take_finished().len(), 20);
        c
    };
    let aware = run(RoutePolicy::AdapterAffinity);
    let rr = run(RoutePolicy::RoundRobin);
    let (hit_aware, hit_rr) =
        (aware.aggregate_adapter_hit_rate(), rr.aggregate_adapter_hit_rate());
    assert!(
        hit_aware > hit_rr,
        "adapter-aware {hit_aware:.3} must strictly beat round-robin {hit_rr:.3}"
    );
    // And not vacuously: after the cold first round, every adapter-aware
    // placement found its weights resident (stable hot subsets)...
    assert!((hit_aware - 15.0 / 20.0).abs() < 1e-12, "got {hit_aware}");
    let st = aware.stats();
    let loads: u64 = st.replicas.iter().map(|r| r.adapter_loads).sum();
    assert_eq!(loads, 5, "adapter-aware: one load per adapter, ever");
    // ...while round-robin keeps re-loading adapters it already paid for.
    let rr_loads: u64 =
        rr.stats().replicas.iter().map(|r| r.adapter_loads).sum();
    assert!(rr_loads > 5, "round-robin should thrash: {rr_loads} loads");
}

#[test]
fn acceptance_c_unbounded_budget_matches_always_resident_bit_exactly() {
    // 4096-block budget dwarfs 4 adapters × 32 pages + the workload's KV:
    // nothing is ever evicted or stalled, so paged mode must reproduce the
    // pre-refactor always-resident run bit-for-bit — same virtual-time
    // makespan, same per-request cache hits and finish times, and the
    // adapter_memory figure's paged row equals its resident baseline row.
    let paged = run_point(4, 4096, true, 6);
    let resident = run_point(4, 4096, false, 6);
    assert_eq!(paged.makespan.to_bits(), resident.makespan.to_bits());
    assert_eq!(paged.ttft_mean.to_bits(), resident.ttft_mean.to_bits());
    assert_eq!(paged.e2e_mean.to_bits(), resident.e2e_mean.to_bits());
    assert_eq!(
        paged.prefix_hit_rate.to_bits(),
        resident.prefix_hit_rate.to_bits()
    );
    assert_eq!(paged.output_fingerprint.len(), resident.output_fingerprint.len());
    for (a, b) in paged
        .output_fingerprint
        .iter()
        .zip(resident.output_fingerprint.iter())
    {
        assert_eq!(a.0, b.0, "request ids diverged");
        assert_eq!(a.1, b.1, "cached tokens diverged for request {}", a.0);
        assert_eq!(
            a.2.to_bits(),
            b.2.to_bits(),
            "finish time diverged for request {}",
            a.0
        );
    }
    // The only difference is observability: the paged run accounts for
    // what the baseline hides.
    assert_eq!(paged.loads, 4);
    assert_eq!(paged.evictions, 0);
    assert_eq!(paged.stall_steps, 0);
    assert_eq!(resident.loads, 0);
}
