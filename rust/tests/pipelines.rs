//! Integration: pipeline drivers × engine × simulator — determinism,
//! conservation, and the paper's comparative claims at integration scope.

use alora_serve::adapter::AdapterId;
use alora_serve::figures::make_engine;
use alora_serve::pipeline::{run_poisson, run_sync, PipelineKind, PipelineSpec, Stage};

#[test]
fn all_pipeline_kinds_complete_and_conserve_blocks() {
    for kind in [
        PipelineKind::BaseAdapter,
        PipelineKind::AdapterBase,
        PipelineKind::BaseAdapterBase,
        PipelineKind::MultiAdapter,
    ] {
        let n_adapters = if kind == PipelineKind::MultiAdapter { 5 } else { 1 };
        let spec = PipelineSpec {
            kind,
            prompt_len: 512,
            base_gen: 64,
            eval_gen: 16,
            adapters: (0..n_adapters).map(AdapterId).collect(),
            base2_gen: 16,
            priority_continuations: false,
        };
        let mut e = make_engine("granite-8b", true, n_adapters);
        let r = run_sync(&mut e, &spec, 3, 9);
        assert!(!r.outputs.is_empty(), "{kind:?} produced no outputs");
        e.check_invariants().unwrap_or_else(|err| panic!("{kind:?}: {err}"));
        // every stage's outputs have monotone timelines
        for (stage, out) in &r.outputs {
            let t = &out.timeline;
            assert!(
                t.arrival <= t.first_scheduled
                    && t.first_scheduled <= t.first_token
                    && t.first_token <= t.finished,
                "{kind:?} {stage:?}: non-monotone timeline {t:?}"
            );
        }
    }
}

#[test]
fn sync_driver_is_deterministic_across_runs() {
    let spec = PipelineSpec::base_adapter(1024, 64, 16);
    let run_once = || {
        let mut e = make_engine("granite-8b", true, 1);
        let r = run_sync(&mut e, &spec, 4, 5);
        (
            r.makespan,
            r.eval_latencies().mean("e2e"),
            r.outputs.len(),
            e.metrics.generated_tokens,
        )
    };
    assert_eq!(run_once(), run_once());
}

#[test]
fn async_driver_matches_request_count_at_all_rates() {
    let spec = PipelineSpec::base_adapter(128, 32, 8);
    for rate in [0.5, 8.0, 64.0] {
        let mut e = make_engine("granite-8b", true, 1);
        let r = run_poisson(&mut e, &spec, 25, rate, 3);
        let base1 = r.outputs.iter().filter(|(s, _)| *s == Stage::Base1).count();
        let evals = r.outputs.iter().filter(|(s, _)| matches!(s, Stage::Eval(_))).count();
        assert_eq!((base1, evals), (25, 25), "rate {rate}");
        e.check_invariants().unwrap();
    }
}

#[test]
fn alora_advantage_holds_in_every_pipeline_kind() {
    for kind in [
        PipelineKind::BaseAdapter,
        PipelineKind::BaseAdapterBase,
        PipelineKind::MultiAdapter,
    ] {
        let n_adapters = if kind == PipelineKind::MultiAdapter { 5 } else { 1 };
        let spec = PipelineSpec {
            kind,
            prompt_len: 4096,
            base_gen: 128,
            eval_gen: 16,
            adapters: (0..n_adapters).map(AdapterId).collect(),
            base2_gen: 16,
            priority_continuations: false,
        };
        let mut ea = make_engine("granite-8b", true, n_adapters);
        let ra = run_sync(&mut ea, &spec, 4, 7);
        let mut el = make_engine("granite-8b", false, n_adapters);
        let rl = run_sync(&mut el, &spec, 4, 7);
        let a = ra.eval_latencies().mean("e2e");
        let l = rl.eval_latencies().mean("e2e");
        assert!(
            l / a > 1.5,
            "{kind:?}: aLoRA should win, got {:.2}x (a={a:.4}, l={l:.4})",
            l / a
        );
    }
}

#[test]
fn makespan_improves_too() {
    // Not just per-stage: the whole pipeline completes earlier with reuse.
    let spec = PipelineSpec::base_adapter(8192, 256, 16);
    let mut ea = make_engine("granite-8b", true, 1);
    let ra = run_sync(&mut ea, &spec, 4, 11);
    let mut el = make_engine("granite-8b", false, 1);
    let rl = run_sync(&mut el, &spec, 4, 11);
    assert!(rl.makespan > ra.makespan, "lora {} vs alora {}", rl.makespan, ra.makespan);
}

#[test]
fn bigger_models_bigger_savings() {
    // Paper: "benefits scaling by model size".
    let spec = PipelineSpec::base_adapter(16384, 128, 16);
    let mut speedups = Vec::new();
    for model in ["granite-8b", "llama-70b", "mistral-large-2"] {
        let mut ea = make_engine(model, true, 1);
        let ra = run_sync(&mut ea, &spec, 2, 13);
        let mut el = make_engine(model, false, 1);
        let rl = run_sync(&mut el, &spec, 2, 13);
        speedups.push(rl.eval_latencies().mean("e2e") / ra.eval_latencies().mean("e2e"));
    }
    assert!(
        speedups[2] > speedups[0],
        "mistral-large-2 should gain more than granite-8b: {speedups:?}"
    );
}
