//! Acceptance tests for the conversation-first v1 serving API.
//!
//! Pins the ISSUE-4 acceptance criteria:
//! - a multi-turn multi-adapter session submitting per-turn deltas through
//!   `/v1/sessions/{id}/turns` achieves a strictly higher aggregate prefix
//!   hit-rate and strictly lower mean TTFT than the same workload replayed
//!   as full-prompt `POST /generate` calls (engine-level and over HTTP);
//! - streamed token sequences are byte-identical to non-streaming output;
//! - legacy `/generate` and `/pipeline` responses are bit-identical to the
//!   pre-refactor wire shape;
//! plus the satellites: session tenant isolation over HTTP, the
//! structured error envelope, and the streaming smoke the CI
//! `make server-smoke` target runs.

use std::io::{Read, Write};
use std::net::TcpStream;

use alora_serve::adapter::AdapterId;
use alora_serve::config::presets;
use alora_serve::config::EngineConfig;
use alora_serve::coordinator::{spec, Coordinator};
use alora_serve::engine::Engine;
use alora_serve::pipeline::workload;
use alora_serve::request::{ModelTarget, SamplingParams};
use alora_serve::server::Server;
use alora_serve::session::SessionManager;
use alora_serve::simulator::SimExecutor;
use alora_serve::util::json::Json;

// ---------------------------------------------------------------------------
// Helpers.

/// Small-cache config: 128 KV blocks, so unrelated traffic between a
/// conversation's turns genuinely evicts unpinned blocks.
fn small_cache_cfg() -> EngineConfig {
    let mut cfg = presets::granite_8b();
    cfg.cache.max_kv_tokens = 2048; // 128 blocks of 16
    cfg.scheduler.max_seq_len = 2048;
    cfg
}

fn engine_with(cfg: &EngineConfig) -> Engine<SimExecutor> {
    let reg = workload::build_registry(2, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(cfg);
    Engine::with_registry(cfg.clone(), reg, exec)
}

fn http(addr: std::net::SocketAddr, req: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn request(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> String {
    http(
        addr,
        &format!(
            "{method} {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
    request(addr, "POST", path, body)
}

/// Body of a Content-Length response (single-line JSON = last line).
fn body_json(resp: &str) -> Json {
    Json::parse(resp.lines().last().unwrap()).unwrap_or_else(|e| {
        panic!("unparseable body in:\n{resp}\n{e}");
    })
}

/// Parse a chunked SSE response into (event, data) pairs.
fn sse_events(resp: &str) -> Vec<(String, Json)> {
    let mut out = Vec::new();
    let mut current: Option<String> = None;
    for line in resp.lines() {
        if let Some(e) = line.strip_prefix("event: ") {
            current = Some(e.to_string());
        } else if let Some(d) = line.strip_prefix("data: ") {
            let name = current.take().expect("data without event");
            out.push((name, Json::parse(d).unwrap()));
        }
    }
    out
}

fn tokens_json(tokens: &[u32]) -> String {
    let strs: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("[{}]", strs.join(","))
}

/// The multi-turn multi-adapter conversation the acceptance comparison
/// replays both ways: (delta, adapter, gen, append).
fn acceptance_turns(vocab: u32) -> Vec<(Vec<u32>, Option<&'static str>, u32, bool)> {
    vec![
        ((0..256).collect(), None, 32, true),
        ((5000..5064).collect(), None, 32, true),
        (workload::invocation_for(vocab, 0), Some("alora-0"), 16, false),
    ]
}

/// Filler prompts for one inter-turn gap: 4 distinct 640-token requests
/// = 164 block allocations through the 128-block pool, cycling every
/// unreferenced cached block out (4 × ceil(648/16) = 4 × 41).
fn filler_prompts(gap: u32) -> Vec<Vec<u32>> {
    (0..4)
        .map(|i| {
            let base = 20_000 + gap * 10_000 + i * 1_000;
            (base..base + 640).collect()
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Acceptance: sessions beat full-prompt replay (engine level).

#[test]
fn session_delta_turns_beat_full_prompt_replay_engine_level() {
    let cfg = small_cache_cfg();
    let vocab = cfg.model.vocab_size;
    // Both runs submit in the same order => identical request ids =>
    // identical (deterministic) token streams, so the workloads are the
    // same byte-for-byte and only the serving mode differs.
    let run_filler = |e: &mut Engine<SimExecutor>, gap: u32| {
        for p in filler_prompts(gap) {
            let id = e
                .submit(
                    ModelTarget::Base,
                    p,
                    SamplingParams { max_new_tokens: 8, ..Default::default() },
                )
                .unwrap();
            e.run_to_completion(id);
        }
    };

    // Session mode: delta turns through the session layer.
    let mut se = engine_with(&cfg);
    let mut mgr = SessionManager::new();
    let sid = mgr.create(0);
    let mut session_turns = Vec::new();
    for (gap, (delta, adapter, gen, append)) in acceptance_turns(vocab).into_iter().enumerate() {
        let target = match adapter {
            None => ModelTarget::Base,
            Some(_) => ModelTarget::Adapter(AdapterId(0)),
        };
        let rec = mgr.run_turn(&mut se, sid, target, delta, gen, append).unwrap();
        session_turns.push(rec);
        if gap + 1 < 3 {
            run_filler(&mut se, gap as u32);
        }
    }

    // Replay mode: the same conversation as one-shot full-prompt
    // submissions (what /generate clients do), history tracked client-side.
    let mut re = engine_with(&cfg);
    let mut history: Vec<u32> = Vec::new();
    let mut replay = Vec::new();
    for (gap, (delta, adapter, gen, append)) in acceptance_turns(vocab).into_iter().enumerate() {
        let target = match adapter {
            None => ModelTarget::Base,
            Some(_) => ModelTarget::Adapter(AdapterId(0)),
        };
        let mut prompt = history.clone();
        prompt.extend(&delta);
        let id = re
            .submit(
                target,
                prompt,
                SamplingParams { max_new_tokens: gen, ..Default::default() },
            )
            .unwrap();
        let out = re.run_to_completion(id);
        if append {
            history.extend(&delta);
            history.extend(&out.output_tokens);
        }
        replay.push(out);
        if gap + 1 < 3 {
            run_filler(&mut re, gap as u32);
        }
    }

    // Same workload: every turn produced identical tokens.
    for (s, r) in session_turns.iter().zip(&replay) {
        assert_eq!(s.output_tokens, r.output_tokens, "turn {:?}", s.turn);
        assert_eq!(s.prompt_len, r.prompt_len);
    }

    // Strictly higher aggregate prefix hit-rate over the turns...
    let s_hit: usize = session_turns.iter().map(|t| t.cached_tokens).sum();
    let r_hit: usize = replay.iter().map(|o| o.num_cached_tokens).sum();
    let queried: usize = session_turns.iter().map(|t| t.prompt_len).sum();
    let s_rate = s_hit as f64 / queried as f64;
    let r_rate = r_hit as f64 / queried as f64;
    assert!(
        s_rate > r_rate,
        "session hit-rate {s_rate:.3} must strictly beat replay {r_rate:.3}"
    );
    // ...the leases make the follow-ups land warm despite the churn:
    assert_eq!(r_hit, 0, "filler churn wipes the replayed conversation");
    assert!(s_hit >= 600, "leased chain survives: {s_hit} tokens hit");
    // ...and at the engine aggregate too (fillers identical in both).
    assert!(se.kv_stats().hit_rate() > re.kv_stats().hit_rate());

    // Strictly lower mean TTFT.
    let s_ttft: f64 =
        session_turns.iter().map(|t| t.ttft_s).sum::<f64>() / session_turns.len() as f64;
    let r_ttft: f64 =
        replay.iter().map(|o| o.timeline.ttft()).sum::<f64>() / replay.len() as f64;
    assert!(
        s_ttft < r_ttft,
        "session mean TTFT {s_ttft:.6}s must strictly beat replay {r_ttft:.6}s"
    );
    // First turns are identical (both cold) — the win is the follow-ups.
    assert_eq!(session_turns[0].ttft_s, replay[0].timeline.ttft());
    assert!(session_turns[1].ttft_s < replay[1].timeline.ttft());
    assert!(session_turns[2].ttft_s < replay[2].timeline.ttft());

    mgr.delete(&mut se, sid).unwrap();
    se.check_invariants().unwrap();
    re.check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// Acceptance: the same comparison over HTTP.

#[test]
fn session_delta_turns_beat_generate_replay_over_http() {
    let cfg = small_cache_cfg();
    let vocab = cfg.model.vocab_size;
    let run_filler_http = |addr: std::net::SocketAddr, gap: u32| {
        for p in filler_prompts(gap) {
            let body =
                format!(r#"{{"prompt": {}, "max_new_tokens": 8}}"#, tokens_json(&p));
            assert!(post(addr, "/generate", &body).contains("200 OK"));
        }
    };

    // Session server: delta turns through the v1 API.
    let mut srv_s = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let sid = body_json(&post(srv_s.addr(), "/v1/sessions", "{}"))
        .get("session")
        .and_then(Json::as_u64)
        .unwrap();
    let mut session_turns: Vec<Json> = Vec::new();
    for (gap, (delta, adapter, gen, append)) in acceptance_turns(vocab).into_iter().enumerate() {
        let adapter_field = match adapter {
            None => "null".to_string(),
            Some(a) => format!("\"{a}\""),
        };
        let body = format!(
            r#"{{"tokens": {}, "adapter": {adapter_field}, "max_new_tokens": {gen}, "append": {append}}}"#,
            tokens_json(&delta)
        );
        let r = post(srv_s.addr(), &format!("/v1/sessions/{sid}/turns"), &body);
        assert!(r.contains("200 OK"), "{r}");
        session_turns.push(body_json(&r));
        if gap + 1 < 3 {
            run_filler_http(srv_s.addr(), gap as u32);
        }
    }

    // Replay server: identical workload as full-prompt /generate calls.
    let mut srv_r = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let mut history: Vec<u32> = Vec::new();
    let mut replay: Vec<Json> = Vec::new();
    for (gap, (delta, adapter, gen, append)) in acceptance_turns(vocab).into_iter().enumerate() {
        let adapter_field = match adapter {
            None => "null".to_string(),
            Some(a) => format!("\"{a}\""),
        };
        let mut prompt = history.clone();
        prompt.extend(&delta);
        let body = format!(
            r#"{{"prompt": {}, "adapter": {adapter_field}, "max_new_tokens": {gen}}}"#,
            tokens_json(&prompt)
        );
        let r = post(srv_r.addr(), "/generate", &body);
        assert!(r.contains("200 OK"), "{r}");
        let j = body_json(&r);
        if append {
            history.extend(&delta);
            let toks: Vec<u32> = j.get("tokens").and_then(Json::u32_vec).unwrap();
            history.extend(&toks);
        }
        replay.push(j);
        if gap + 1 < 3 {
            run_filler_http(srv_r.addr(), gap as u32);
        }
    }

    // Identical token streams (same ids, deterministic simulator).
    for (s, r) in session_turns.iter().zip(&replay) {
        assert_eq!(
            s.get("tokens").and_then(Json::u32_vec),
            r.get("tokens").and_then(Json::u32_vec)
        );
    }
    // Strictly higher aggregate hit-rate through the session API.
    let s_hit: f64 = session_turns
        .iter()
        .map(|t| t.get("cached_tokens").and_then(Json::as_f64).unwrap())
        .sum();
    let r_hit: f64 = replay
        .iter()
        .map(|o| {
            // /generate reports the rate; prompt lengths match the
            // session turns' (same workload).
            o.get("cache_hit_rate").and_then(Json::as_f64).unwrap()
        })
        .sum();
    assert!(s_hit >= 600.0, "leased chain survives over HTTP: {s_hit}");
    assert_eq!(r_hit, 0.0, "replayed conversation fully evicted");
    // Strictly lower mean TTFT.
    let mean = |v: &[Json], key: &str| -> f64 {
        v.iter().map(|j| j.get(key).and_then(Json::as_f64).unwrap()).sum::<f64>()
            / v.len() as f64
    };
    let s_ttft = mean(&session_turns, "ttft_s");
    let r_ttft = mean(&replay, "ttft_s");
    assert!(
        s_ttft < r_ttft,
        "v1 sessions mean TTFT {s_ttft:.6}s must strictly beat /generate replay {r_ttft:.6}s"
    );

    // /metrics surfaces the per-turn series and lease gauges.
    let m = http(srv_s.addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
    assert!(m.contains("alora_serve_turns_total 3"), "{m}");
    assert!(m.contains("alora_serve_sessions_created_total 1"));
    srv_s.shutdown();
    srv_r.shutdown();
}

// ---------------------------------------------------------------------------
// Acceptance: streamed token sequences are byte-identical.

#[test]
fn streamed_turns_byte_identical_to_non_streaming() {
    // Two fresh identical servers run the same 3-turn session — one
    // streaming, one not. Determinism + identical submission order means
    // the streamed token events must reproduce the non-streaming arrays
    // byte-for-byte.
    let cfg = presets::granite_8b();
    let vocab = cfg.model.vocab_size;
    let mut srv_a = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let mut srv_b = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let sid_a = body_json(&post(srv_a.addr(), "/v1/sessions", "{}"))
        .get("session")
        .and_then(Json::as_u64)
        .unwrap();
    let sid_b = body_json(&post(srv_b.addr(), "/v1/sessions", "{}"))
        .get("session")
        .and_then(Json::as_u64)
        .unwrap();

    for (delta, adapter, gen, append) in acceptance_turns(vocab) {
        let adapter_field = match adapter {
            None => "null".to_string(),
            Some(a) => format!("\"{a}\""),
        };
        let mk_body = |stream: bool| {
            format!(
                r#"{{"tokens": {}, "adapter": {adapter_field}, "max_new_tokens": {gen}, "append": {append}, "stream": {stream}}}"#,
                tokens_json(&delta)
            )
        };
        // Streaming on A.
        let ra = post(srv_a.addr(), &format!("/v1/sessions/{sid_a}/turns"), &mk_body(true));
        assert!(ra.contains("200 OK"), "{ra}");
        assert!(ra.contains("Transfer-Encoding: chunked"), "{ra}");
        let events = sse_events(&ra);
        assert_eq!(events.first().map(|(e, _)| e.as_str()), Some("started"), "{ra}");
        assert_eq!(events.last().map(|(e, _)| e.as_str()), Some("finished"));
        let streamed: Vec<u32> = events
            .iter()
            .filter(|(e, _)| e == "token")
            .map(|(_, d)| d.get("token").and_then(Json::as_u64).unwrap() as u32)
            .collect();
        assert_eq!(streamed.len(), gen as usize);
        // Token event indices are 0..gen in order; clocks monotone.
        let idxs: Vec<u64> = events
            .iter()
            .filter(|(e, _)| e == "token")
            .map(|(_, d)| d.get("index").and_then(Json::as_u64).unwrap())
            .collect();
        assert_eq!(idxs, (0..gen as u64).collect::<Vec<_>>());
        let finished = &events.last().unwrap().1;
        assert_eq!(
            finished.get("tokens").and_then(Json::u32_vec).unwrap(),
            streamed,
            "finished summary matches the streamed sequence"
        );
        // Non-streaming on B: byte-identical tokens.
        let rb = post(srv_b.addr(), &format!("/v1/sessions/{sid_b}/turns"), &mk_body(false));
        assert!(rb.contains("200 OK"), "{rb}");
        let jb = body_json(&rb);
        assert_eq!(
            jb.get("tokens").and_then(Json::u32_vec).unwrap(),
            streamed,
            "streamed tokens byte-identical to the non-streaming output"
        );
        // The finished-event summary equals the non-streaming body.
        assert_eq!(finished, &jb);
    }

    // Both sessions accumulated the same history.
    let ga = body_json(&request(srv_a.addr(), "GET", &format!("/v1/sessions/{sid_a}"), ""));
    let gb = body_json(&request(srv_b.addr(), "GET", &format!("/v1/sessions/{sid_b}"), ""));
    assert_eq!(
        ga.get("tokens").and_then(Json::u32_vec),
        gb.get("tokens").and_then(Json::u32_vec)
    );
    srv_a.shutdown();
    srv_b.shutdown();
}

// ---------------------------------------------------------------------------
// Acceptance: legacy endpoints stay bit-identical.

#[test]
fn legacy_generate_and_pipeline_bit_identical() {
    let cfg = presets::granite_8b();
    // /generate: the HTTP body must equal the legacy wire shape built
    // from an identical direct-engine run (same ids, same virtual
    // timeline — the server adds no work before the submission).
    let mut srv = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let prompt: Vec<u32> = (0..64).collect();
    let body = format!(r#"{{"prompt": {}, "max_new_tokens": 4}}"#, tokens_json(&prompt));
    let r = post(srv.addr(), "/generate", &body);
    assert!(r.contains("200 OK"), "{r}");
    let served = r.lines().last().unwrap().to_string();
    srv.shutdown();

    let mut e = engine_with(&cfg);
    let id = e
        .submit(
            ModelTarget::Base,
            prompt,
            SamplingParams { max_new_tokens: 4, ..Default::default() },
        )
        .unwrap();
    let out = e.run_to_completion(id);
    let expected = Json::obj(vec![
        ("id", Json::num(out.id.0 as f64)),
        (
            "tokens",
            Json::Arr(out.output_tokens.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("e2e_s", Json::num(out.timeline.e2e())),
        ("ttft_s", Json::num(out.timeline.ttft())),
        ("itl_s", Json::num(out.itl())),
        ("cache_hit_rate", Json::num(out.cache_hit_rate())),
        ("preemptions", Json::num(out.preemptions as f64)),
    ])
    .to_string();
    assert_eq!(served, expected, "legacy /generate response drifted");

    // /pipeline: a linear chain (parent completion idles the engine, so
    // chaining time is deterministic) must serve exactly what a direct
    // event-drive of the same graph produces.
    let p128: Vec<u32> = (0..128).collect();
    let spec_body = format!(
        r#"{{"stages": [
            {{"name": "draft", "gen": 8, "prompt": [{}]}},
            {{"name": "check", "adapter": "alora-0", "gen": 4, "invoke": true,
              "prompt": [{{"prompt_of": "draft"}}, {{"output_of": "draft"}}]}},
            {{"name": "final", "gen": 4,
              "prompt": [{{"prompt_of": "check"}}, {{"output_of": "check"}}]}}
        ]}}"#,
        tokens_json(&p128)
    );
    let mut srv = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let r = post(srv.addr(), "/pipeline", &spec_body);
    assert!(r.contains("200 OK"), "{r}");
    let served = r.lines().last().unwrap().to_string();
    srv.shutdown();

    let mut e = engine_with(&cfg);
    let graph = {
        let j = Json::parse(&spec_body).unwrap();
        spec::graph_from_json(&j, &e.registry).unwrap()
    };
    let result = Coordinator::run_event(&mut e, vec![graph], &[0.0]).unwrap();
    let expected = spec::result_to_json(&result).to_string();
    assert_eq!(served, expected, "legacy /pipeline response drifted");
}

// ---------------------------------------------------------------------------
// Satellite: session tenant isolation over HTTP.

#[test]
fn session_tenant_isolation_over_http() {
    let cfg = presets::granite_8b();
    let mut srv = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let create = |salt: &str| {
        body_json(&post(srv.addr(), "/v1/sessions", &format!(r#"{{"cache_salt": {salt}}}"#)))
            .get("session")
            .and_then(Json::as_u64)
            .unwrap()
    };
    let a = create("\"tenant-a\"");
    let b = create("\"tenant-b\"");
    let a2 = create("\"tenant-a\"");
    let prompt: Vec<u32> = (0..128).collect();
    let turn = |sid: u64| {
        let body = format!(r#"{{"tokens": {}, "max_new_tokens": 4}}"#, tokens_json(&prompt));
        let r = post(srv.addr(), &format!("/v1/sessions/{sid}/turns"), &body);
        assert!(r.contains("200 OK"), "{r}");
        body_json(&r).get("cached_tokens").and_then(Json::as_u64).unwrap()
    };
    assert_eq!(turn(a), 0, "cold tenant A");
    assert_eq!(turn(b), 0, "tenant B must never hit tenant A's blocks");
    assert!(turn(a2) > 0, "same-tenant session shares the tenant's prefix");
    srv.shutdown();
}

// ---------------------------------------------------------------------------
// Satellite: session lifecycle + error envelope over the v1 surface.

#[test]
fn session_lifecycle_document_and_errors() {
    let cfg = presets::granite_8b();
    let mut srv = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let addr = srv.addr();

    // Create + one turn.
    let sid = body_json(&post(addr, "/v1/sessions", "{}"))
        .get("session")
        .and_then(Json::as_u64)
        .unwrap();
    let delta: Vec<u32> = (0..64).collect();
    let r = post(
        addr,
        &format!("/v1/sessions/{sid}/turns"),
        &format!(r#"{{"tokens": {}, "max_new_tokens": 8}}"#, tokens_json(&delta)),
    );
    assert!(r.contains("200 OK"), "{r}");
    let turn = body_json(&r);
    let out_tokens = turn.get("tokens").and_then(Json::u32_vec).unwrap();

    // The session document reconstructs the conversation.
    let doc = body_json(&request(addr, "GET", &format!("/v1/sessions/{sid}"), ""));
    assert_eq!(doc.get("history_len").and_then(Json::as_u64), Some(72));
    let mut expect = delta.clone();
    expect.extend(&out_tokens);
    assert_eq!(doc.get("tokens").and_then(Json::u32_vec).unwrap(), expect);
    assert_eq!(doc.get("turns").and_then(Json::as_arr).unwrap().len(), 1);
    assert!(doc.get("leased_blocks").and_then(Json::as_u64).unwrap() > 0);
    assert_eq!(doc.get("in_flight").and_then(Json::as_bool), Some(false));
    // The listing shows it.
    let list = body_json(&request(addr, "GET", "/v1/sessions", ""));
    assert_eq!(list.get("count").and_then(Json::as_u64), Some(1));

    // Error paths: unknown session (GET / POST / DELETE), unknown
    // adapter, malformed turn body — all structured envelopes.
    let assert_code = |resp: &str, status: &str, code: &str| {
        assert!(resp.contains(status), "{resp}");
        let j = body_json(resp);
        assert_eq!(
            j.get("error").unwrap().get("code").and_then(Json::as_str),
            Some(code),
            "{resp}"
        );
    };
    assert_code(&request(addr, "GET", "/v1/sessions/999", ""), "404", "session_not_found");
    assert_code(
        &post(addr, "/v1/sessions/999/turns", r#"{"tokens": [1]}"#),
        "404",
        "session_not_found",
    );
    assert_code(&request(addr, "DELETE", "/v1/sessions/999", ""), "404", "session_not_found");
    assert_code(
        &post(addr, &format!("/v1/sessions/{sid}/turns"), r#"{"tokens": [1], "adapter": "ghost"}"#),
        "404",
        "unknown_adapter",
    );
    assert_code(
        &post(addr, &format!("/v1/sessions/{sid}/turns"), r#"{"tokens": "nope"}"#),
        "400",
        "invalid_request",
    );
    assert_code(&post(addr, &format!("/v1/sessions/{sid}/turns"), "{not json"), "400", "invalid_json");
    assert_code(&post(addr, &format!("/v1/sessions/{sid}/turns"), ""), "400", "missing_body");
    // An empty first... an empty turn on a session WITH history is legal
    // ("continue generating"); on a fresh session it is not.
    let fresh = body_json(&post(addr, "/v1/sessions", "{}"))
        .get("session")
        .and_then(Json::as_u64)
        .unwrap();
    assert_code(
        &post(addr, &format!("/v1/sessions/{fresh}/turns"), r#"{"max_new_tokens": 4}"#),
        "400",
        "invalid_request",
    );

    // Delete releases the lease and removes the session.
    let d = body_json(&request(addr, "DELETE", &format!("/v1/sessions/{sid}"), ""));
    assert_eq!(d.get("deleted").and_then(Json::as_u64), Some(sid));
    assert_eq!(d.get("turns").and_then(Json::as_u64), Some(1));
    assert_code(&request(addr, "GET", &format!("/v1/sessions/{sid}"), ""), "404", "session_not_found");
    let m = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
    assert!(m.contains("alora_serve_leased_blocks 0"), "{m}");
    assert!(m.contains("alora_serve_sessions_closed_total 1"), "{m}");
    srv.shutdown();
}

// ---------------------------------------------------------------------------
// Satellite (ISSUE 5): stuck-409 regression — a client that disconnects
// mid-SSE must not leave the session's turn in flight forever.

#[test]
fn client_disconnect_mid_stream_does_not_wedge_the_session() {
    let cfg = presets::granite_8b();
    let mut srv = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let addr = srv.addr();
    let sid = body_json(&post(addr, "/v1/sessions", "{}"))
        .get("session")
        .and_then(Json::as_u64)
        .unwrap();
    // Start a long streaming turn and slam the connection shut without
    // reading a byte: the server's SSE writes hit a dead socket
    // mid-stream, which is exactly the path that used to leave the
    // pending turn set forever (every later turn 409'd).
    {
        let delta: Vec<u32> = (0..256).collect();
        let body = format!(
            r#"{{"tokens": {}, "max_new_tokens": 128, "stream": true}}"#,
            tokens_json(&delta)
        );
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(
            format!(
                "POST /v1/sessions/{sid}/turns HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        s.shutdown(std::net::Shutdown::Both).ok();
    }
    // The cleanup path either applies the finished turn (it completed
    // server-side; only the client missed the final event) or aborts the
    // dead one — either way the session accepts a new turn. A transient
    // 409 while the disconnected turn is still genuinely running is
    // legal; a permanent one is the regression.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let r = post(
            addr,
            &format!("/v1/sessions/{sid}/turns"),
            r#"{"tokens": [1,2,3], "max_new_tokens": 4}"#,
        );
        if r.contains("200 OK") {
            break;
        }
        assert!(r.contains("409"), "unexpected response: {r}");
        assert!(
            std::time::Instant::now() < deadline,
            "session wedged in turn_in_flight after client disconnect: {r}"
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let doc = body_json(&request(addr, "GET", &format!("/v1/sessions/{sid}"), ""));
    assert_eq!(doc.get("in_flight").and_then(Json::as_bool), Some(false));
    srv.shutdown();
}

// ---------------------------------------------------------------------------
// Satellite: the streaming smoke `make server-smoke` runs — session
// create → 3 streaming delta turns → delete.

#[test]
fn streaming_smoke_session_lifecycle() {
    let cfg = presets::granite_8b();
    let mut srv = Server::start(engine_with(&cfg), "127.0.0.1:0").unwrap();
    let addr = srv.addr();
    let sid = body_json(&post(addr, "/v1/sessions", r#"{"cache_salt": "smoke"}"#))
        .get("session")
        .and_then(Json::as_u64)
        .unwrap();
    let mut prev_cached = None;
    for t in 0..3u32 {
        let delta: Vec<u32> = (t * 100..t * 100 + 48).collect();
        let body = format!(
            r#"{{"tokens": {}, "max_new_tokens": 8, "stream": true}}"#,
            tokens_json(&delta)
        );
        let r = post(addr, &format!("/v1/sessions/{sid}/turns"), &body);
        assert!(r.contains("200 OK"), "turn {t}: {r}");
        let events = sse_events(&r);
        let names: Vec<&str> = events.iter().map(|(e, _)| e.as_str()).collect();
        assert_eq!(names.first(), Some(&"started"), "turn {t}: {names:?}");
        assert_eq!(names.last(), Some(&"finished"));
        assert_eq!(names.iter().filter(|n| **n == "token").count(), 8);
        let fin = &events.last().unwrap().1;
        assert_eq!(fin.get("turn").and_then(Json::as_u64), Some(t as u64));
        let cached = fin.get("cached_tokens").and_then(Json::as_u64).unwrap();
        if let Some(prev) = prev_cached {
            assert!(cached > prev, "turn {t} must extend the warm chain");
        }
        prev_cached = Some(cached);
    }
    let d = request(addr, "DELETE", &format!("/v1/sessions/{sid}"), "");
    assert!(d.contains("200 OK"), "{d}");
    let m = http(addr, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
    assert!(m.contains("alora_serve_turns_total 3"), "{m}");
    assert!(m.contains("alora_serve_stream_subscriptions_total 3"), "{m}");
    assert!(m.contains("alora_serve_stream_token_events_total 24"), "{m}");
    srv.shutdown();
}
