//! Integration: the stage-graph coordinator end to end — dependency
//! ordering, cross-stage KV reuse over a fan-out/fan-in DAG, and
//! coordinator-aware trace round-trips.

use alora_serve::adapter::AdapterId;
use alora_serve::coordinator::{Coordinator, StageGraph, StageId};
use alora_serve::figures::make_engine;
use alora_serve::pipeline::trace::{replay_stages, Trace};
use alora_serve::pipeline::workload;
use alora_serve::request::ModelTarget;
use alora_serve::util::rng::Rng;

/// draft (base) → {eval-0, eval-1} (adapters, fan-out) → consolidate
/// (base, fan-in).
fn fan_graph(prompt: Vec<u32>, vocab: u32) -> StageGraph {
    let mut g = StageGraph::new();
    let draft = g.root("draft", ModelTarget::Base, prompt, 64);
    let e0 = g.chain(
        "eval-0",
        ModelTarget::Adapter(AdapterId(0)),
        draft,
        workload::invocation_for(vocab, 0),
        16,
    );
    let e1 = g.chain(
        "eval-1",
        ModelTarget::Adapter(AdapterId(1)),
        draft,
        workload::invocation_for(vocab, 1),
        16,
    );
    g.consolidate("consolidate", ModelTarget::Base, draft, &[e0, e1], Vec::new(), 32);
    g
}

fn find<'a>(
    r: &'a alora_serve::coordinator::CoordinatorResult,
    conv: usize,
    name: &str,
) -> &'a alora_serve::coordinator::StageOutput {
    r.outputs
        .iter()
        .find(|o| o.conversation == conv && o.name == name)
        .unwrap_or_else(|| panic!("missing stage {name} of conversation {conv}"))
}

#[test]
fn dag_respects_dependency_order() {
    let mut e = make_engine("granite-8b", true, 2);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(7);
    let graphs: Vec<StageGraph> = (0..4)
        .map(|_| fan_graph(workload::prompt(&mut rng, 512, vocab), vocab))
        .collect();
    let r = Coordinator::run_event(&mut e, graphs, &[0.0, 0.2, 0.4, 0.6]).unwrap();
    assert_eq!(r.outputs.len(), 16); // 4 conversations × 4 stages

    for conv in 0..4 {
        let draft = find(&r, conv, "draft");
        let consolidate = find(&r, conv, "consolidate");
        for eval in ["eval-0", "eval-1"] {
            let ev = find(&r, conv, eval);
            // evals are submitted only once the draft finished...
            assert!(
                ev.output.timeline.arrival >= draft.output.timeline.finished,
                "conv {conv}: {eval} started before draft finished"
            );
            // ...and the consolidation only once both evals finished.
            assert!(
                consolidate.output.timeline.arrival >= ev.output.timeline.finished,
                "conv {conv}: consolidate started before {eval} finished"
            );
        }
        // timelines are internally monotone
        for o in r.outputs.iter().filter(|o| o.conversation == conv) {
            let t = &o.output.timeline;
            assert!(
                t.arrival <= t.first_scheduled
                    && t.first_scheduled <= t.first_token
                    && t.first_token <= t.finished,
                "conv {conv} {}: non-monotone timeline {t:?}",
                o.name
            );
        }
    }
    e.check_invariants().unwrap();
}

#[test]
fn downstream_stages_hit_parent_kv() {
    let mut e = make_engine("granite-8b", true, 2);
    let vocab = e.cfg.model.vocab_size;
    let mut rng = Rng::new(13);
    let graphs: Vec<StageGraph> = (0..4)
        .map(|_| fan_graph(workload::prompt(&mut rng, 1024, vocab), vocab))
        .collect();
    let r = Coordinator::run_event(&mut e, graphs, &[0.0; 4]).unwrap();
    // every non-root stage of every conversation reuses its parents' KV
    for o in &r.outputs {
        if o.name != "draft" {
            assert!(
                o.output.cache_hit_rate() > 0.0,
                "conv {} stage {}: no prefix-cache hits",
                o.conversation,
                o.name
            );
        }
    }
    // and substantially so, on average
    for name in ["eval-0", "eval-1", "consolidate"] {
        assert!(r.hit_rate_of(name) > 0.5, "{name}: {}", r.hit_rate_of(name));
    }
    // per-stage-name series landed in the engine metrics
    for name in ["draft", "eval-0", "eval-1", "consolidate"] {
        assert_eq!(e.metrics.stage_latencies(name).map(|s| s.count()), Some(4), "{name}");
    }
    // the LoRA baseline gets no cross-model reuse at the eval stages
    let mut el = make_engine("granite-8b", false, 2);
    let mut rng = Rng::new(13);
    let graphs: Vec<StageGraph> = (0..4)
        .map(|_| fan_graph(workload::prompt(&mut rng, 1024, vocab), vocab))
        .collect();
    let rl = Coordinator::run_event(&mut el, graphs, &[0.0; 4]).unwrap();
    assert_eq!(rl.hit_rate_of("eval-0"), 0.0);
    assert_eq!(rl.hit_rate_of("eval-1"), 0.0);
}

#[test]
fn trace_roundtrip_reproduces_per_stage_latencies() {
    let vocab = 49_155;
    let trace = Trace::synthesize_conversations(6, 4.0, 256, 32, 8, 16, 2, vocab, 11);

    // Run the original trace.
    let run = |t: &Trace| {
        let mut e = make_engine("granite-8b", true, 2);
        let r = replay_stages(&mut e, t).unwrap();
        let mut stats: Vec<(String, usize, f64, f64)> = r
            .stage_names()
            .into_iter()
            .map(|n| {
                let lat = r.latencies_of(&n);
                (n.clone(), lat.count(), lat.mean("e2e"), r.hit_rate_of(&n))
            })
            .collect();
        stats.sort_by(|a, b| a.0.cmp(&b.0));
        (stats, r.makespan)
    };
    let (orig_stats, orig_makespan) = run(&trace);
    assert_eq!(orig_stats.len(), 4); // base1, base2, eval-0, eval-1
    for (name, count, _, _) in &orig_stats {
        assert_eq!(*count, 6, "{name}");
    }

    // save → load: identical trace...
    let path = std::env::temp_dir().join("alora_coordinator_trace_test.json");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(trace, loaded);

    // ...and replaying it reproduces the per-stage latencies exactly
    // (virtual time is deterministic).
    let (replayed_stats, replayed_makespan) = run(&loaded);
    assert_eq!(orig_stats, replayed_stats);
    assert_eq!(orig_makespan, replayed_makespan);

    // chained stages rehydrate their parents' KV after the round trip too
    for (name, _, _, hit) in &replayed_stats {
        if name != "base1" {
            assert!(*hit > 0.0, "{name}: no hits after round trip");
        }
    }
}

#[test]
fn four_stage_ids_and_levels_are_exposed() {
    let g = fan_graph(vec![1; 64], 49_155);
    assert_eq!(g.len(), 4);
    assert_eq!(g.max_level(), 2);
    assert_eq!(g.roots(), vec![StageId(0)]);
    assert_eq!(g.parents(StageId(3)), &[StageId(0), StageId(1), StageId(2)]);
}
