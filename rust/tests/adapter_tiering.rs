//! Integration: tiered adapter memory — time-costed host↔device
//! transfers, prefetch, host-tier demotion, heterogeneous fleets
//! (DESIGN.md §20).
//!
//! Acceptance bars (ISSUE 10):
//! (a) with transfer costs on, scheduler prefetch strictly reduces
//!     load-stall steps on an adapter-churn workload;
//! (b) host-tier demotion beats drop-and-reload on reload latency: the
//!     demote arm replaces cold loads with promotions and its makespan is
//!     shorter by exactly the saved setup costs;
//! (c) a heterogeneous fleet strictly beats a homogeneous fleet of equal
//!     TOTAL block budget on aggregate adapter-residency hit-rate;
//! (d) the default config (zero transfer cost, no host tier) is
//!     behaviorally identical to the pre-tiering instantaneous model, and
//!     the prefetch flag is inert at zero cost.

use alora_serve::adapter::AdapterId;
use alora_serve::engine::Engine;
use alora_serve::figures::adapter_tiering::{cfg_for, run_churn, run_fleet, LOAD_BW};
use alora_serve::pipeline::workload;
use alora_serve::request::{ModelTarget, SamplingParams};
use alora_serve::simulator::SimExecutor;

#[test]
fn acceptance_a_prefetch_strictly_reduces_load_stall_steps() {
    // Same churn workload (9 requests cycling 3 adapters on a 96-block
    // device), host tier on in both arms; only the prefetch flag differs.
    let plain = run_churn(96, LOAD_BW, false, 9);
    let prefetch = run_churn(96, LOAD_BW, true, 9);
    assert_eq!(plain.prefetches, 0);
    assert!(prefetch.prefetches >= 1, "prefetch never fired: {prefetch:?}");
    assert!(
        prefetch.stall_steps < plain.stall_steps,
        "prefetch must strictly reduce load stalls: {} vs {}",
        prefetch.stall_steps,
        plain.stall_steps
    );
    // A transfer that matured during the queue wait is admitted warm, so
    // overlap also shows up as residency hit-rate.
    assert!(prefetch.adapter_hit_rate >= plain.adapter_hit_rate);
}

/// Sequential alternation over 2 adapters on a 64-block device (one
/// adapter's weights + KV): every request evicts the other adapter, so
/// every admission after the first two is a reload — promotion when the
/// host tier holds the demoted copy, full-cost cold load when it dropped.
fn alternate(host_blocks: u64) -> alora_serve::figures::adapter_tiering::ChurnResult {
    let mut cfg = cfg_for(host_blocks, LOAD_BW, false);
    cfg.cache.max_kv_tokens = 64 * cfg.cache.block_size as u64;
    cfg.cache.host_adapter_blocks = host_blocks;
    let reg = workload::build_registry(2, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    let mut e = Engine::with_registry(cfg, reg, exec);
    let params = SamplingParams { max_new_tokens: 4, ..Default::default() };
    for k in 0..6u32 {
        let prompt = vec![500 + k; 17];
        e.submit(ModelTarget::Adapter(AdapterId(k % 2)), prompt, params).unwrap();
        e.run_until_idle();
    }
    let rs = e.residency().stats();
    alora_serve::figures::adapter_tiering::ChurnResult {
        loads: rs.loads,
        evictions: rs.evictions,
        demotions: rs.demotions,
        promotions: rs.promotions,
        host_drops: rs.host_drops,
        prefetches: rs.prefetches,
        stall_steps: rs.load_stall_steps,
        adapter_hit_rate: rs.hit_rate(),
        ttft_mean: e.metrics.all.mean("ttft"),
        makespan: e.clock(),
    }
}

#[test]
fn acceptance_b_demotion_beats_drop_and_reload() {
    // 32-block host tier holds exactly the one adapter evicted at a time.
    let demote = alternate(32);
    let drop = alternate(0);
    // Drop arm: 2 cold loads + 4 full-cost reloads, nothing ever demoted.
    assert_eq!(drop.loads, 6, "{drop:?}");
    assert_eq!((drop.demotions, drop.promotions, drop.host_drops), (0, 0, 0));
    // Demote arm: the same 4 reloads become setup-free promotions.
    assert_eq!(demote.loads, 2, "{demote:?}");
    assert_eq!(demote.promotions, 4, "{demote:?}");
    assert!(demote.demotions >= 4, "{demote:?}");
    assert_eq!(demote.host_drops, 0, "32-block tier never overflows");
    assert!(
        demote.makespan < drop.makespan,
        "demotion must shorten reloads: {} vs {}",
        demote.makespan,
        drop.makespan
    );
    // The two arms differ ONLY in per-reload setup cost: the makespan gap
    // is the 4 promotions' saved setup time (cfg_for pins setup = 2ms).
    let saved = drop.makespan - demote.makespan;
    assert!(
        (saved - 4.0 * 2.0e-3).abs() < 1e-6,
        "gap should be promotions x setup: saved {saved}"
    );
}

#[test]
fn acceptance_c_heterogeneous_fleet_beats_homogeneous_at_equal_budget() {
    // 5 adapters x 32 blocks over two replicas, 192 total blocks in both
    // fleets. 136+56 packs 4+1 with KV headroom; 96+96 pigeonholes three
    // adapters onto one replica whose pool they fill completely, so it
    // must evict one every round, forever.
    let hetero = run_fleet(true, 4);
    let homo = run_fleet(false, 4);
    assert_eq!(hetero.loads, 5, "clean packing loads each adapter once: {hetero:?}");
    assert_eq!(hetero.evictions, 0, "{hetero:?}");
    assert!(homo.loads >= 8, "equal-split fleet must thrash: {homo:?}");
    assert!(homo.evictions >= 1, "{homo:?}");
    // Round 1 cold, rounds 2..4 all warm: 15/20 admissions hit.
    assert!((hetero.aggregate_adapter_hit_rate - 0.75).abs() < 1e-12, "{hetero:?}");
    assert!(
        hetero.aggregate_adapter_hit_rate > homo.aggregate_adapter_hit_rate + 0.1,
        "hetero {} vs homo {}",
        hetero.aggregate_adapter_hit_rate,
        homo.aggregate_adapter_hit_rate
    );
}

#[test]
fn acceptance_d_default_zero_cost_is_unchanged_and_prefetch_is_inert() {
    // bw = 0 collapses the state machine to the pre-tiering instantaneous
    // model: loads complete inline, nothing is ever in flight, and none
    // of the new tier counters can move.
    let base = run_churn(0, 0.0, false, 9);
    assert_eq!(
        (base.demotions, base.promotions, base.host_drops, base.prefetches),
        (0, 0, 0, 0),
        "{base:?}"
    );
    // The prefetch flag must be a documented no-op at zero cost: same
    // counters, same stalls, same clock, same TTFT — bit-identical run.
    let with_flag = run_churn(0, 0.0, true, 9);
    assert_eq!(base, with_flag);
    // And the costed arms really charge time the zero-cost model hid.
    let costed = run_churn(0, LOAD_BW, false, 9);
    assert!(costed.makespan > base.makespan, "{costed:?} vs {base:?}");
}
