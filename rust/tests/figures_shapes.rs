//! Integration: every figure harness runs in quick mode and reproduces the
//! paper's qualitative shape (who wins, scaling direction, crossovers).
//! Full-size sweeps live in `cargo bench --bench bench_fig*`.

use alora_serve::figures;

#[test]
fn run_all_quick_produces_every_table() {
    let tables = figures::run_all(true);
    let ids: Vec<&str> = tables.iter().map(|t| t.id.as_str()).collect();
    for want in [
        "table1", "fig6", "fig6-speedup", "fig7", "fig8", "fig9", "fig10-eval",
        "fig10-base2", "fig10-multi", "fig11", "fig12", "fig13", "fig14", "fig15",
        "cluster_scaling", "adapter_memory", "adapter_tiering", "failover",
        "migration",
    ] {
        assert!(ids.contains(&want), "missing table `{want}` in {ids:?}");
    }
    for t in &tables {
        assert!(!t.rows.is_empty(), "{} has no rows", t.id);
        assert_eq!(t.rows.len(), t.data.len(), "{}: rows/data mismatch", t.id);
    }
}

#[test]
fn run_by_id_individual_figures() {
    for id in ["table1", "fig7"] {
        let tables = figures::run_by_id(id, true);
        assert!(!tables.is_empty());
    }
}

#[test]
#[should_panic(expected = "unknown figure id")]
fn unknown_figure_id_panics() {
    figures::run_by_id("fig99", true);
}

#[test]
fn headline_speedup_directionality_matches_paper() {
    // Fig 6 speedup columns: aLoRA wins everywhere, more at longer prompts;
    // Fig 8: more at higher rates. Both already unit-asserted; here we
    // assert across-figure consistency: the async plateau speedup at the
    // highest quick rate should be >= the sync speedup at the shortest
    // prompt (both granite-8b).
    let fig6 = figures::fig6::run(true);
    let sync_short = fig6[1].col("e2e_x")[0];
    let fig8 = figures::fig8::run(true);
    let sp = fig8.col("e2e_speedup");
    let async_high = sp[sp.len() - 1];
    assert!(sync_short > 1.0 && async_high > 1.0);
}
