//! Offline stand-in for the `anyhow` crate (API-compatible subset).
//!
//! The build environment has no crates.io access (DESIGN.md §7), so the
//! workspace vendors the small slice of anyhow the codebase actually uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and
//! the [`Context`] extension trait. Error chains are flattened to strings
//! ("context: source") rather than kept as a source chain — good enough
//! for CLI/test diagnostics, trivially swappable for the real crate once a
//! registry is reachable.

use std::fmt;

/// A boxed, type-erased error with a display message.
///
/// Like the real `anyhow::Error`, this deliberately does **not** implement
/// `std::error::Error`, which is what lets the blanket
/// `impl<E: std::error::Error> From<E> for Error` coexist with the
/// reflexive `From<Error> for Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Construct from a concrete error value.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        Error { msg: e.to_string() }
    }

    /// Prepend a context line, anyhow-style ("context: source").
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    /// Anything convertible into [`crate::Error`] for context-wrapping.
    /// Two non-overlapping impls: concrete std errors, and `Error` itself
    /// (which does not implement `std::error::Error`).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::new(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (implicit captures supported).
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::msg(::std::format!($($t)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // std error converts via `?`
        ensure!(n > 0, "must be positive, got {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse("3").unwrap(), 3);
        assert!(parse("x").is_err());
        assert_eq!(parse("0").unwrap_err().to_string(), "must be positive, got 0");
    }

    #[test]
    fn context_on_result_option_and_error() {
        let r: Result<()> = Err(anyhow!("inner"));
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let o: Option<u8> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let io: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "disk",
        ));
        assert_eq!(
            io.with_context(|| format!("step {}", 2)).unwrap_err().to_string(),
            "step 2: disk"
        );
    }

    #[test]
    fn bail_short_circuits() {
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }
}
