//! HTTP serving demo: start the server on the simulated engine, issue a
//! few /generate calls (base + adapter), print /metrics, shut down.
//!
//!     cargo run --release --example serve_http

use std::io::{Read, Write};
use std::net::TcpStream;

use alora_serve::engine::Engine;
use alora_serve::pipeline::workload;
use alora_serve::server::Server;
use alora_serve::simulator::SimExecutor;

fn http(addr: std::net::SocketAddr, req: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> String {
    http(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn main() -> anyhow::Result<()> {
    let cfg = alora_serve::config::presets::granite_8b();
    let reg = workload::build_registry(2, cfg.model.vocab_size, true);
    let exec = SimExecutor::new(&cfg);
    let engine = Engine::with_registry(cfg, reg, exec);
    let mut srv = Server::start(engine, "127.0.0.1:0")?;
    println!("server listening on http://{}\n", srv.addr());

    // base request
    let body = r#"{"prompt": [11,12,13,14,15,16,17,18,19,20,21,22,23,24,25,26], "max_new_tokens": 8}"#;
    let resp = post(srv.addr(), "/generate", body);
    println!("POST /generate (base):\n{}\n", resp.lines().last().unwrap_or(""));

    // adapter request over the same prefix (cross-model cache reuse)
    let inv = workload::invocation_for(49_155, 0);
    let mut prompt: Vec<u32> = (11..27).collect();
    prompt.extend(inv);
    let body = format!(
        r#"{{"prompt": {:?}, "adapter": "alora-0", "max_new_tokens": 4}}"#,
        prompt
    );
    let resp = post(srv.addr(), "/generate", &body);
    println!("POST /generate (alora-0):\n{}\n", resp.lines().last().unwrap_or(""));

    let metrics = http(
        srv.addr(),
        "GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n",
    );
    println!("GET /metrics (excerpt):");
    for line in metrics.lines().filter(|l| l.starts_with("alora_serve")).take(12) {
        println!("  {line}");
    }

    srv.shutdown();
    println!("\nserver stopped.");
    Ok(())
}
