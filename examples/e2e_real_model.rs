//! END-TO-END VALIDATION (DESIGN.md §5): load the AOT-compiled tiny model
//! on the PJRT CPU client and serve batched multi-turn base→aLoRA→base
//! conversations through the FULL engine stack — scheduler, block manager,
//! base-aligned prefix cache, activation masks, real forward passes — then
//! verify the cross-model reuse numerics against the goldens exported by
//! aot.py, and report latency/throughput + cache hit rates.
//!
//!     make artifacts && cargo run --release --example e2e_real_model
//!
//! This is the proof that all three layers compose: Pallas kernels (L1)
//! inside the jitted step function (L2) executed from the rust coordinator
//! (L3), with KV blocks physically reused across models.

use std::path::PathBuf;

use alora_serve::adapter::{AdapterId, AdapterRegistry};
use alora_serve::config::presets;
use alora_serve::engine::Engine;
use alora_serve::request::{ModelTarget, SamplingParams};
use alora_serve::runtime::{RealExecutor, TinyModel};
use alora_serve::util::json::Json;
use alora_serve::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = TinyModel::default_dir();
    anyhow::ensure!(
        TinyModel::artifacts_present(&dir),
        "artifacts missing at {} — run `make artifacts` first",
        dir.display()
    );

    println!("loading {} via PJRT CPU…", dir.join("tiny_step.hlo.txt").display());
    let t0 = std::time::Instant::now();
    let exec = RealExecutor::load(&dir, 0)?;
    let manifest = exec.manifest().clone();
    println!(
        "compiled in {:.2}s  (vocab {}, d_model {}, {} layers, max_seq {})",
        t0.elapsed().as_secs_f64(),
        manifest.vocab_size,
        manifest.d_model,
        manifest.n_layers,
        manifest.max_seq_len
    );

    let cfg = presets::tiny();
    let registry = AdapterRegistry::tiny_default(
        manifest.n_adapters as u32,
        manifest.vocab_size as u32,
        manifest.invocation_tokens[0].len() as u32,
    );
    let mut engine = Engine::with_registry(cfg, registry, exec);

    // ---------------------------------------------------------------------
    // Part 1 — golden-checked single conversation (numeric validation).
    // ---------------------------------------------------------------------
    let golden = Json::parse_file(&golden_path(&dir))?;
    let prompt = golden.req("prompt").u32_vec().unwrap();
    let adapter_id = golden.req("adapter_id").as_u64().unwrap() as u32;
    let base_next = golden.req("base_next_token").as_u64().unwrap() as u32;

    let base = engine.submit(
        ModelTarget::Base,
        prompt.clone(),
        SamplingParams { max_new_tokens: 1, ..Default::default() },
    )?;
    let base_out = engine.run_to_completion(base);
    anyhow::ensure!(
        base_out.output_tokens[0] == base_next,
        "golden mismatch: base argmax {} != expected {}",
        base_out.output_tokens[0],
        base_next
    );
    println!("\n[golden] base argmax token matches aot.py: {base_next}");

    // aLoRA evaluation reusing the base blocks.
    let eval_tokens = golden.req("eval_tokens").u32_vec().unwrap();
    let alora = engine.submit(
        ModelTarget::Adapter(AdapterId(adapter_id)),
        eval_tokens.clone(),
        SamplingParams { max_new_tokens: 1, ..Default::default() },
    )?;
    let alora_out = engine.run_to_completion(alora);
    let expected_argmax = golden.req("alora_argmax").as_u64().unwrap() as u32;
    anyhow::ensure!(
        alora_out.output_tokens[0] == expected_argmax,
        "golden mismatch: aLoRA argmax {} != expected {} (cross-model reuse broken?)",
        alora_out.output_tokens[0],
        expected_argmax
    );
    println!(
        "[golden] aLoRA argmax with REUSED base KV blocks matches full-recompute golden: {} \
         (hit rate {:.0}%)",
        expected_argmax,
        alora_out.cache_hit_rate() * 100.0
    );
    anyhow::ensure!(alora_out.num_cached_tokens > 0, "expected cross-model cache hits");
    let lora_argmax = golden.req("lora_argmax").as_u64().unwrap() as u32;
    if lora_argmax != expected_argmax {
        println!("[golden] (standard-LoRA argmax differs: {lora_argmax} — adapter semantics distinct)");
    }

    // ---------------------------------------------------------------------
    // Part 2 — batched multi-turn serving workload (latency/throughput).
    // ---------------------------------------------------------------------
    println!("\nserving a batch of multi-turn conversations (real forward passes)…");
    let mut rng = Rng::new(11);
    let n_conv = 4;
    let wall = std::time::Instant::now();
    let mut total_tokens = 0usize;
    let mut eval_hits = Vec::new();
    let mut eval_e2e = Vec::new();
    let mut eval_itl = Vec::new();

    for c in 0..n_conv {
        let vocab = manifest.vocab_size as u32;
        let p = rng.tokens(48 + (c % 2) * 16, vocab, 64);
        // turn 1: base
        let b = engine.submit(
            ModelTarget::Base,
            p.clone(),
            SamplingParams { max_new_tokens: 12, ..Default::default() },
        )?;
        let b_out = engine.run_to_completion(b);
        total_tokens += b_out.prompt_len + b_out.output_tokens.len();

        // turn 2: each adapter evaluates in turn (adapter switching!)
        for a in 0..manifest.n_adapters as u32 {
            let mut ev = p.clone();
            ev.extend(b_out.output_tokens.iter());
            ev.extend(manifest.invocation_tokens[a as usize].iter());
            let e = engine.submit(
                ModelTarget::Adapter(AdapterId(a)),
                ev,
                SamplingParams { max_new_tokens: 6, ..Default::default() },
            )?;
            let e_out = engine.run_to_completion(e);
            total_tokens += e_out.prompt_len + e_out.output_tokens.len();
            eval_hits.push(e_out.cache_hit_rate());
            eval_e2e.push(e_out.timeline.e2e());
            eval_itl.push(e_out.itl());
        }

        // turn 3: base resumes
        let mut cont = p.clone();
        cont.extend(b_out.output_tokens.iter());
        let b2 = engine.submit(
            ModelTarget::Base,
            cont,
            SamplingParams { max_new_tokens: 8, ..Default::default() },
        )?;
        let b2_out = engine.run_to_completion(b2);
        total_tokens += b2_out.prompt_len + b2_out.output_tokens.len();
    }

    let wall_s = wall.elapsed().as_secs_f64();
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!("\n=== end-to-end results (REAL model, {} conversations) ===", n_conv);
    println!("  requests served      : {}", engine.metrics.requests_finished);
    println!("  tokens processed     : {total_tokens}");
    println!("  wall time            : {wall_s:.2}s  ({:.1} tok/s)", total_tokens as f64 / wall_s);
    println!("  adapter-eval hit rate: {:.1}% (cross-model KV reuse)", mean(&eval_hits) * 100.0);
    println!("  adapter-eval e2e     : {:.4}s mean", mean(&eval_e2e));
    println!("  adapter-eval ITL     : {:.4}s mean", mean(&eval_itl));
    println!("  engine cache hit rate: {:.1}%", engine.metrics.cache_hit_rate() * 100.0);
    println!(
        "  executor model time  : {:.2}s, block copy time {:.3}s",
        engine.executor().model_time,
        engine.executor().copy_time
    );

    anyhow::ensure!(mean(&eval_hits) > 0.5, "adapter evals should mostly hit cache");
    println!("\nOK — all three layers compose; cross-model reuse is numerically exact.");
    Ok(())
}

fn golden_path(dir: &std::path::Path) -> PathBuf {
    dir.join("golden.json")
}
