//! Asynchronous serving under Poisson load (paper §4.3): sweep the arrival
//! rate and watch the aLoRA speedup grow with utilization, then print the
//! Prometheus metrics snapshot of the last engine.
//!
//!     cargo run --release --example async_serving

use alora_serve::figures::make_engine;
use alora_serve::pipeline::{run_poisson, PipelineSpec};

fn main() {
    let spec = PipelineSpec::base_adapter(256, 256, 16);
    let n = 200;
    println!("async base-adapter, prompt 256 / gen 256 / eval 16, n={n} conversations\n");
    println!("{:>12} {:>14} {:>14} {:>10}", "rate(req/s)", "LoRA e2e(s)", "aLoRA e2e(s)", "speedup");

    let mut last_prom = String::new();
    for rate in [0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
        let mut ea = make_engine("granite-8b", true, 1);
        let ra = run_poisson(&mut ea, &spec, n, rate, 42);
        let mut el = make_engine("granite-8b", false, 1);
        let rl = run_poisson(&mut el, &spec, n, rate, 42);
        let a = ra.eval_latencies().mean("e2e");
        let l = rl.eval_latencies().mean("e2e");
        println!("{rate:>12} {l:>14.4} {a:>14.4} {:>9.1}x", l / a);
        last_prom = ea.metrics.render_prometheus();
    }

    println!("\n--- /metrics snapshot of the final aLoRA engine (excerpt) ---");
    for line in last_prom.lines().filter(|l| !l.starts_with('#')).take(14) {
        println!("{line}");
    }
}
