//! Quickstart: serve one base→aLoRA→base conversation on the simulated
//! Granite-8B engine and print the paper's Table-2 metrics.
//!
//!     cargo run --release --example quickstart

use alora_serve::adapter::AdapterId;
use alora_serve::config::presets;
use alora_serve::engine::Engine;
use alora_serve::pipeline::workload;
use alora_serve::request::{ModelTarget, SamplingParams};
use alora_serve::simulator::SimExecutor;
use alora_serve::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Engine: Granite-8B on a (simulated) H100, base-aligned prefix
    //    caching ON — the paper's system. Flip `base_aligned_hashing` to
    //    false for the vanilla-vLLM LoRA baseline.
    let cfg = presets::granite_8b();
    let registry = workload::build_registry(1, cfg.model.vocab_size, /*alora=*/ true);
    let exec = SimExecutor::new(&cfg);
    let mut engine = Engine::with_registry(cfg, registry, exec);

    // 2. A long conversation with the base model.
    let mut rng = Rng::new(0);
    let prompt = workload::prompt(&mut rng, 8192, engine.cfg.model.vocab_size);
    let base = engine.submit(
        ModelTarget::Base,
        prompt.clone(),
        SamplingParams { max_new_tokens: 256, ..Default::default() },
    )?;
    let base_out = engine.run_to_completion(base);
    println!(
        "base turn   : e2e {:.3}s  ttft {:.3}s  ({} prompt + {} generated tokens)",
        base_out.timeline.e2e(),
        base_out.timeline.ttft(),
        base_out.prompt_len,
        base_out.output_tokens.len()
    );

    // 3. aLoRA "intrinsic" evaluates the conversation — reusing the base
    //    model's KV-cache blocks across models (the paper's contribution).
    let mut eval = prompt.clone();
    eval.extend(base_out.output_tokens.iter());
    eval.extend(workload::invocation_for(engine.cfg.model.vocab_size, 0));
    let alora = engine.submit(
        ModelTarget::Adapter(AdapterId(0)),
        eval,
        SamplingParams { max_new_tokens: 16, ..Default::default() },
    )?;
    let alora_out = engine.run_to_completion(alora);
    println!(
        "aLoRA eval  : e2e {:.3}s  ttft {:.3}s  cache hit rate {:.1}%",
        alora_out.timeline.e2e(),
        alora_out.timeline.ttft(),
        alora_out.cache_hit_rate() * 100.0
    );

    // 4. Base model resumes the conversation, reusing its own blocks.
    let mut next = prompt.clone();
    next.extend(base_out.output_tokens.iter());
    next.extend(alora_out.output_tokens.iter());
    let base2 = engine.submit(
        ModelTarget::Base,
        next,
        SamplingParams { max_new_tokens: 64, ..Default::default() },
    )?;
    let base2_out = engine.run_to_completion(base2);
    println!(
        "base resume : e2e {:.3}s  ttft {:.3}s  cache hit rate {:.1}%",
        base2_out.timeline.e2e(),
        base2_out.timeline.ttft(),
        base2_out.cache_hit_rate() * 100.0
    );

    println!("\nengine metrics:");
    for (k, v) in engine.metrics.summary() {
        println!("  {k:>20}: {v:.6}");
    }
    Ok(())
}
