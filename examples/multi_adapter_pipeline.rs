//! Multi-adapter stage-graph pipeline through the L3 coordinator.
//!
//! A 6-stage DAG per conversation (beyond the paper's fixed §4.4.1 shape):
//!
//!     draft (base) ──┬─> eval-0 (aLoRA intrinsic) ──┐
//!                    ├─> eval-1                     ├─> consolidate (base) ─> verify (aLoRA)
//!                    └─> eval-2                     ┘
//!
//! fan-out to 3 adapter "intrinsics" (uncertainty quantification,
//! jailbreak detection, …), fan-in consolidation, then a final adapter
//! verification over the consolidated answer. The coordinator submits
//! each stage the moment its parents finish, so every non-root stage
//! lands while its parents' KV blocks are cache-hot — compared against
//! the standard-LoRA baseline, which re-prefills at every hand-off.
//!
//!     cargo run --release --example multi_adapter_pipeline

use alora_serve::adapter::AdapterId;
use alora_serve::coordinator::{Coordinator, StageGraph, StageId};
use alora_serve::figures::make_engine;
use alora_serve::pipeline::workload;
use alora_serve::request::ModelTarget;
use alora_serve::util::rng::Rng;

fn build_dag(prompt: Vec<u32>, vocab: u32, n_adapters: u32) -> StageGraph {
    let mut g = StageGraph::new();
    let draft = g.root("draft", ModelTarget::Base, prompt, 256);
    let evals: Vec<StageId> = (0..n_adapters)
        .map(|a| {
            g.chain(
                &format!("eval-{a}"),
                ModelTarget::Adapter(AdapterId(a)),
                draft,
                workload::invocation_for(vocab, a),
                16,
            )
        })
        .collect();
    let consolidate =
        g.consolidate("consolidate", ModelTarget::Base, draft, &evals, Vec::new(), 64);
    g.chain(
        "verify",
        ModelTarget::Adapter(AdapterId(0)),
        consolidate,
        workload::invocation_for(vocab, 0),
        16,
    );
    g
}

fn main() {
    let conversations = 16;
    let n_adapters = 3;
    println!(
        "6-stage DAG: draft -> {n_adapters} parallel evals -> consolidate -> verify \
         ({conversations} conversations, granite-8b sim)\n"
    );

    for (label, alora) in [("aLoRA (ours)", true), ("LoRA (baseline)", false)] {
        let mut engine = make_engine("granite-8b", alora, n_adapters);
        let vocab = engine.cfg.model.vocab_size;
        let mut rng = Rng::new(42);
        let graphs: Vec<StageGraph> = (0..conversations)
            .map(|_| build_dag(workload::prompt(&mut rng, 256, vocab), vocab, n_adapters))
            .collect();
        let arrivals = vec![0.0; conversations];
        let result =
            Coordinator::run_event(&mut engine, graphs, &arrivals).expect("pipeline run");

        println!("{label}:");
        println!(
            "  {:<12} {:>6} {:>9} {:>9} {:>9} {:>9} {:>7}",
            "stage", "count", "e2e(s)", "queue(s)", "prefill(s)", "decode(s)", "hit%"
        );
        for name in result.stage_names() {
            let lat = result.latencies_of(&name);
            let hit = result.hit_rate_of(&name);
            println!(
                "  {:<12} {:>6} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>6.0}%",
                name,
                lat.count(),
                lat.mean("e2e"),
                lat.mean("queue"),
                lat.mean("prefill"),
                lat.mean("decode"),
                hit * 100.0
            );
            if alora && name != "draft" {
                assert!(hit > 0.0, "non-root stage `{name}` should reuse parent KV");
            }
        }
        println!("  pipeline makespan : {:.3}s\n", result.makespan);
    }

    println!(
        "The LoRA baseline re-prefills (prompt + upstream outputs) at every\n\
         hand-off; queueing from those prefills also delays the downstream\n\
         stages (Fig 10). With aLoRA every non-root stage reports a nonzero\n\
         prefix-cache hit rate: its parents' KV blocks are reused in place."
    );
}
