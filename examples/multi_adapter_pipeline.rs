//! Multi-adapter pipeline (paper §4.4.1): base → 5 parallel aLoRA
//! "intrinsics" (uncertainty quantification, jailbreak detection, …) →
//! consolidated base call, compared against the standard-LoRA baseline.
//!
//!     cargo run --release --example multi_adapter_pipeline

use alora_serve::adapter::AdapterId;
use alora_serve::figures::make_engine;
use alora_serve::pipeline::{run_sync, PipelineKind, PipelineSpec};

fn main() {
    let spec = PipelineSpec {
        kind: PipelineKind::MultiAdapter,
        prompt_len: 256,
        base_gen: 256,
        eval_gen: 16,
        adapters: (0..5).map(AdapterId).collect(),
        base2_gen: 16, priority_continuations: false,
    };
    let batch = 16;

    println!("base → 5 parallel adapters → consolidated base  (batch {batch}, granite-8b sim)\n");
    for (label, alora) in [("aLoRA (ours)", true), ("LoRA (baseline)", false)] {
        let mut engine = make_engine("granite-8b", alora, 5);
        let r = run_sync(&mut engine, &spec, batch, 42);
        let ev = r.eval_latencies();
        let b2 = r.base2_latencies();
        println!("{label}:");
        println!(
            "  adapter evals ({}): e2e {:.3}s  queue {:.3}s  prefill {:.3}s  decode {:.3}s  hit {:.0}%",
            ev.count(),
            ev.mean("e2e"),
            ev.mean("queue"),
            ev.mean("prefill"),
            ev.mean("decode"),
            r.eval_hit_rate() * 100.0
        );
        println!(
            "  final base call   : ttft {:.3}s  queue {:.3}s  e2e {:.3}s",
            b2.mean("ttft"),
            b2.mean("queue"),
            b2.mean("e2e")
        );
        println!("  pipeline makespan : {:.3}s\n", r.makespan);
    }
    println!(
        "The LoRA baseline re-prefills (prompt + generation) once per adapter;\n\
         queuing from those prefills also delays the final base call (Fig 10)."
    );
}
